//! HLO-like intermediate representation of one training iteration.
//!
//! A [`TrainingGraph`] is the unit the whole system operates on: the model
//! zoo builds one, the profiler annotates it, the fusion transforms rewrite
//! it, the simulator schedules it, and the search explores the space of its
//! rewrites. It corresponds to the paper's "HLO module of the whole DNN
//! model" (DisCo §3.1): forward ops, backward ops, AllReduce instructions
//! for every gradient tensor, and optimizer-update ops.
//!
//! Nodes are stored in an arena (`Vec<Node>`) with tombstones: fusion
//! transforms mark absorbed nodes `deleted` rather than re-indexing, so a
//! candidate rewrite is a cheap clone + local edits (important for the
//! search hot path).

pub mod op;
pub mod shape;
pub mod builder;
pub mod serial;
pub mod hlo_import;

pub use op::{OpKind, PatternClass};
pub use shape::{DType, Shape};

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::sync::OnceLock;

/// Index of a node within its graph's arena.
pub type NodeId = usize;

/// Which phase of the training iteration an op belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Role {
    Forward,
    Backward,
    Optimizer,
    Comm,
    Param,
}

impl Role {
    pub fn name(self) -> &'static str {
        match self {
            Role::Forward => "fwd",
            Role::Backward => "bwd",
            Role::Optimizer => "opt",
            Role::Comm => "comm",
            Role::Param => "param",
        }
    }

    pub fn from_name(s: &str) -> Option<Role> {
        match s {
            "fwd" => Some(Role::Forward),
            "bwd" => Some(Role::Backward),
            "opt" => Some(Role::Optimizer),
            "comm" => Some(Role::Comm),
            "param" => Some(Role::Param),
            _ => None,
        }
    }
}

/// Descriptor of an original (pre-fusion) op retained inside a fused group.
/// This is exactly the per-node feature record the GNN estimator consumes
/// (paper §4.3.1: op type, input/output sizes, profiled execution time).
#[derive(Debug, Clone, PartialEq)]
pub struct OrigOp {
    /// Node id in the *original* (unfused) graph — stable identity.
    pub orig_id: NodeId,
    pub kind: OpKind,
    pub flops: f64,
    pub bytes_in: f64,
    pub bytes_out: f64,
    /// Profiled single-op execution time in ms (0 until profiled).
    pub time_ms: f64,
    /// True if this op instance is a duplicate-fusion replica whose compute
    /// is re-paid inside the group.
    pub duplicated: bool,
}

/// The subgraph of original ops inside a fused computation op.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FusedGroup {
    pub ops: Vec<OrigOp>,
    /// Directed edges (producer index, consumer index) into `ops`.
    pub edges: Vec<(usize, usize)>,
}

impl FusedGroup {
    /// Deterministic signature for estimator caching: same member ops (by
    /// original id + duplication flag) and same internal wiring → same cost.
    pub fn signature(&self) -> u64 {
        let mut h = DefaultHasher::new();
        // Order-independent over ops: sort keys first.
        let mut keys: Vec<(NodeId, bool)> =
            self.ops.iter().map(|o| (o.orig_id, o.duplicated)).collect();
        keys.sort_unstable();
        keys.hash(&mut h);
        let mut edges: Vec<(NodeId, NodeId)> = self
            .edges
            .iter()
            .map(|&(a, b)| (self.ops[a].orig_id, self.ops[b].orig_id))
            .collect();
        edges.sort_unstable();
        edges.hash(&mut h);
        h.finish()
    }

    pub fn total_flops(&self) -> f64 {
        self.ops.iter().map(|o| o.flops).sum()
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

/// Chunking descriptor for a communication tensor (CoCoNet-style
/// chunked collectives): the AllReduce's payload is transferred as
/// `count` equal-latency chunks on the (in-order) channel, and each
/// chunk becomes visible to pipelinable consumers as soon as it lands
/// instead of at whole-tensor completion. `count <= 1` is canonically
/// equivalent to "no chunking" — every consumer of this descriptor
/// (simulator, fingerprint, serializer) treats it as absent, which is
/// what makes the degenerate-case bit-identity contract (DESIGN.md §13)
/// hold by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkSpec {
    /// Number of chunks the tensor is split into (meaningful when >= 2).
    pub count: u32,
}

impl ChunkSpec {
    pub fn new(count: u32) -> ChunkSpec {
        ChunkSpec { count }
    }

    /// True when this descriptor actually changes scheduling.
    #[inline]
    pub fn is_active(&self) -> bool {
        self.count >= 2
    }

    /// Exact byte split: `total` bytes (an integral f64 for every tensor
    /// the builder produces) divided into `count` chunks with u64
    /// arithmetic — the remainder spreads one byte each over the first
    /// chunks, so the per-chunk sizes always sum EXACTLY to the input.
    pub fn chunk_bytes(&self, total: f64) -> Vec<f64> {
        let k = self.count.max(1) as u64;
        let t = total.max(0.0) as u64;
        let per = t / k;
        let rem = t % k;
        (0..k).map(|i| (per + u64::from(i < rem)) as f64).collect()
    }
}

/// Which collective implements a gradient's cross-replica reduction
/// (ZeRO/FSDP sharding dimension, DESIGN.md §16). `AllReduce` is the
/// paper's DDP baseline: every rank ends with the full reduced gradient.
/// `ReduceScatterAllGather` splits the collective: a reduce-scatter
/// leaves each rank with its 1/W shard of the reduced gradient (the
/// optimizer then updates only that shard), and an all-gather of the
/// updated parameter shards restores replication — schedulable into the
/// next iteration's forward pass (the overlap window the simulator
/// models).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    AllReduce,
    ReduceScatterAllGather,
}

impl CollectiveKind {
    pub fn name(self) -> &'static str {
        match self {
            CollectiveKind::AllReduce => "ar",
            CollectiveKind::ReduceScatterAllGather => "rs_ag",
        }
    }

    pub fn from_name(s: &str) -> Option<CollectiveKind> {
        match s {
            "ar" => Some(CollectiveKind::AllReduce),
            "rs_ag" => Some(CollectiveKind::ReduceScatterAllGather),
            _ => None,
        }
    }
}

/// Per-tensor placement state in the sharded-training state machine
/// (CoCoNet / commfuser tagging model). The simulator does not branch on
/// these at run time — they document and validate the legality rules the
/// sharded schedule obeys (see [`ShardSpec::placement_after`]):
///
/// * gradient before its collective: `Partial` (each rank holds its
///   local, un-reduced contribution);
/// * after reduce-scatter: `Sharded` (rank `r` holds the reduced shard
///   `r`);
/// * parameter shard after the optimizer step: still `Sharded`;
/// * after all-gather: `Replicated` (every rank holds the full tensor);
/// * `OnDemand` marks a tensor materialized lazily right before use —
///   the prefetch window the all-gather is scheduled into.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Placement {
    Replicated,
    Sharded,
    Partial,
    OnDemand,
}

impl Placement {
    pub fn name(self) -> &'static str {
        match self {
            Placement::Replicated => "replicated",
            Placement::Sharded => "sharded",
            Placement::Partial => "partial",
            Placement::OnDemand => "ondemand",
        }
    }
}

/// Sharding descriptor for a gradient collective (ZeRO/FSDP-style
/// sharded-state training). Mirrors [`ChunkSpec`]'s canonical-`None`
/// contract: `Some(ShardSpec { kind: AllReduce })` is semantically
/// identical to no descriptor at all — every consumer (simulator,
/// fingerprint, serializer) treats the inactive form as absent, so
/// "never sharded" and "sharded then reset" graphs are bit-identical
/// (DESIGN.md §16).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// Collective implementing the reduction.
    pub kind: CollectiveKind,
}

impl ShardSpec {
    pub fn new(kind: CollectiveKind) -> ShardSpec {
        ShardSpec { kind }
    }

    /// True when this descriptor actually changes scheduling.
    #[inline]
    pub fn is_active(&self) -> bool {
        self.kind == CollectiveKind::ReduceScatterAllGather
    }

    /// Exact per-rank byte split of a `total`-byte tensor over `workers`
    /// ranks, in u64 arithmetic — the remainder spreads one byte each
    /// over the first ranks, so the shard sizes always sum EXACTLY to
    /// the input (the conservation property the reduce-scatter and
    /// all-gather phases are tested against).
    pub fn shard_bytes(total: f64, workers: usize) -> Vec<f64> {
        let w = workers.max(1) as u64;
        let t = total.max(0.0) as u64;
        let per = t / w;
        let rem = t % w;
        (0..w).map(|i| (per + u64::from(i < rem)) as f64).collect()
    }

    /// The placement state a tensor is in after each stage of the
    /// sharded schedule — the commfuser state machine the legality rules
    /// encode. `stage` 0 = gradient produced, 1 = after reduce-scatter,
    /// 2 = after optimizer step, 3 = after all-gather.
    pub fn placement_after(stage: u8) -> Placement {
        match stage {
            0 => Placement::Partial,
            1 | 2 => Placement::Sharded,
            _ => Placement::Replicated,
        }
    }
}

/// One instruction of the training graph.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub kind: OpKind,
    pub role: Role,
    /// Producers of this node's operands (live node ids). Fusion
    /// transforms redirect these when consumers are rewired.
    pub inputs: Vec<NodeId>,
    /// The inputs this instruction had when first created — never mutated
    /// by rewrites. Fused-group internal wiring is derived from these.
    pub orig_inputs: Vec<NodeId>,
    /// Primary output shape.
    pub shape: Shape,
    pub dtype: DType,
    /// Floating-point operations performed by this op.
    pub flops: f64,
    /// Bytes read from device memory (operand bytes).
    pub bytes_in: f64,
    /// Bytes written to device memory (result bytes).
    pub bytes_out: f64,
    /// For `OpKind::Fused`: the internal subgraph of original ops.
    pub fused: Option<FusedGroup>,
    /// For `OpKind::AllReduce`: ids of the *original* AllReduce instructions
    /// merged into this one (singleton when unfused). Used for neighbor
    /// discovery and byte accounting in tensor fusion.
    pub ar_constituents: Vec<NodeId>,
    /// For `OpKind::AllReduce`: optional chunking descriptor. `None` and
    /// `Some(count <= 1)` mean the same thing — a whole-tensor transfer
    /// (see [`ChunkSpec`]); tensor fusion resets this to `None`.
    pub chunk: Option<ChunkSpec>,
    /// For `OpKind::AllReduce`: optional sharding descriptor. `None` and
    /// `Some(kind = AllReduce)` mean the same thing — a DDP whole-gradient
    /// all-reduce (see [`ShardSpec`]); tensor fusion carries the shared
    /// kind of its (same-kind, by legality) constituents.
    pub shard: Option<ShardSpec>,
    /// Tombstone: true once absorbed by a fusion transform.
    pub deleted: bool,
}

impl Node {
    /// Gradient-tensor bytes carried by an AllReduce node.
    pub fn tensor_bytes(&self) -> f64 {
        debug_assert_eq!(self.kind, OpKind::AllReduce);
        self.bytes_out
    }

    /// Effective chunk count: 1 (whole-tensor) unless an active
    /// [`ChunkSpec`] is present. Canonicalizes `None` ≡ `Some(count<=1)`.
    #[inline]
    pub fn chunk_count(&self) -> u32 {
        match &self.chunk {
            Some(c) if c.is_active() => c.count,
            _ => 1,
        }
    }

    /// Effective collective kind: DDP all-reduce unless an active
    /// [`ShardSpec`] is present. Canonicalizes `None` ≡
    /// `Some(kind = AllReduce)`.
    #[inline]
    pub fn shard_kind(&self) -> CollectiveKind {
        match &self.shard {
            Some(s) if s.is_active() => s.kind,
            _ => CollectiveKind::AllReduce,
        }
    }

    /// True for a live-schedulable collective that runs as
    /// reduce-scatter + all-gather instead of a whole all-reduce.
    #[inline]
    pub fn is_sharded_collective(&self) -> bool {
        self.kind == OpKind::AllReduce && self.shard_kind() == CollectiveKind::ReduceScatterAllGather
    }

    /// Signature used as an estimator cache key. Unfused ops key on
    /// (kind, shape, dtype); fused ops key on their group signature —
    /// the paper's "indexed by op_code and input shape" (§4.2).
    pub fn cost_signature(&self) -> u64 {
        let mut h = DefaultHasher::new();
        match &self.fused {
            Some(g) => {
                1u8.hash(&mut h);
                g.signature().hash(&mut h);
            }
            None => {
                0u8.hash(&mut h);
                self.kind.name().hash(&mut h);
                self.shape.dims.hash(&mut h);
                self.dtype.name().hash(&mut h);
                (self.flops.to_bits(), self.bytes_in.to_bits(), self.bytes_out.to_bits())
                    .hash(&mut h);
            }
        }
        h.finish()
    }
}

/// Validation failures for a graph (used by the search's validity check).
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum GraphError {
    #[error("node {0} references missing/deleted input {1}")]
    DanglingInput(NodeId, NodeId),
    #[error("graph contains a cycle involving node {0}")]
    Cycle(NodeId),
    #[error("node {0} ({1}) of kind {2} may not be fused")]
    InvalidFusion(NodeId, String, String),
    #[error("node {0} id does not match arena index {1}")]
    IdMismatch(NodeId, usize),
}

/// Flat CSR successor adjacency over a graph's node arena: the consumers
/// of node `i` are `targets[offsets[i]..offsets[i + 1]]`, in ascending
/// consumer-id order (matching [`TrainingGraph::successors`]). Two flat
/// allocations instead of one `Vec` per node — this is the search hot
/// path's adjacency representation, cached on the graph and rebuilt
/// lazily after a rewrite invalidates it (see `rust/PERF.md`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SuccCsr {
    pub offsets: Vec<u32>,
    pub targets: Vec<u32>,
}

impl SuccCsr {
    /// Build from scratch in two passes (degree count + prefix sum, fill).
    pub fn build(g: &TrainingGraph) -> SuccCsr {
        let n = g.nodes.len();
        let mut offsets = vec![0u32; n + 1];
        for node in g.live() {
            for &i in &node.inputs {
                offsets[i + 1] += 1;
            }
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let mut cursor: Vec<u32> = offsets[..n].to_vec();
        let mut targets = vec![0u32; offsets[n] as usize];
        for node in g.live() {
            for &i in &node.inputs {
                targets[cursor[i] as usize] = node.id as u32;
                cursor[i] += 1;
            }
        }
        SuccCsr { offsets, targets }
    }

    /// Consumers of node `id`.
    #[inline]
    pub fn row(&self, id: NodeId) -> &[u32] {
        &self.targets[self.offsets[id] as usize..self.offsets[id + 1] as usize]
    }

    /// Number of consumers of node `id`.
    #[inline]
    pub fn out_degree(&self, id: NodeId) -> usize {
        (self.offsets[id + 1] - self.offsets[id]) as usize
    }
}

/// Reusable node-id marker with O(1) epoch-based reset: `reset` bumps a
/// generation counter instead of zero-filling, so clearing between uses
/// is free no matter the graph size. Used by the delta simulator to flag
/// a mutation frontier's one-hop closure per candidate without per-eval
/// allocation. Call [`NodeFlags::reset`] before each use.
#[derive(Debug, Default)]
pub struct NodeFlags {
    epoch: u32,
    marks: Vec<u32>,
}

impl NodeFlags {
    pub fn new() -> NodeFlags {
        NodeFlags::default()
    }

    /// Clear all marks and size for `n` node ids. Keeps capacity.
    pub fn reset(&mut self, n: usize) {
        if self.marks.len() < n {
            self.marks.resize(n, 0);
        }
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // Generation counter wrapped: hard-clear once every 2^32 resets
            // so stale marks from the previous epoch-0 era can't alias.
            self.marks.iter_mut().for_each(|m| *m = 0);
            self.epoch = 1;
        }
    }

    #[inline]
    pub fn mark(&mut self, id: NodeId) {
        self.marks[id] = self.epoch;
    }

    #[inline]
    pub fn is_marked(&self, id: NodeId) -> bool {
        self.marks[id] == self.epoch
    }
}

/// A whole training-iteration graph for one worker replica, plus the
/// data-parallel context (worker count) its AllReduces span.
#[derive(Debug)]
pub struct TrainingGraph {
    pub name: String,
    pub nodes: Vec<Node>,
    /// Number of data-parallel workers (devices) the AllReduces span.
    pub num_workers: usize,
    /// Lazily-built successor adjacency. Invalidation contract: every
    /// mutation that goes through [`TrainingGraph::push`] or the fusion
    /// rewrites resets it; code that edits `nodes` directly must call
    /// [`TrainingGraph::invalidate_adjacency`] before the next
    /// `succ_csr`/`topo_order`/simulation. `validate()` deliberately does
    /// NOT trust this cache.
    adj: OnceLock<SuccCsr>,
}

impl Clone for TrainingGraph {
    fn clone(&self) -> Self {
        // The cache is not carried: clones exist to be mutated (search
        // candidates), so a copied cache would be stale immediately.
        TrainingGraph {
            name: self.name.clone(),
            nodes: self.nodes.clone(),
            num_workers: self.num_workers,
            adj: OnceLock::new(),
        }
    }
}

impl PartialEq for TrainingGraph {
    fn eq(&self, other: &Self) -> bool {
        self.name == other.name
            && self.num_workers == other.num_workers
            && self.nodes == other.nodes
    }
}

impl TrainingGraph {
    pub fn new(name: &str, num_workers: usize) -> TrainingGraph {
        TrainingGraph {
            name: name.to_string(),
            nodes: Vec::new(),
            num_workers,
            adj: OnceLock::new(),
        }
    }

    /// Assemble a graph from already-built parts (deserialization).
    pub fn from_parts(name: String, nodes: Vec<Node>, num_workers: usize) -> TrainingGraph {
        TrainingGraph { name, nodes, num_workers, adj: OnceLock::new() }
    }

    // ---- structure access ---------------------------------------------------

    /// Live (non-tombstoned) nodes.
    pub fn live(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(|n| !n.deleted)
    }

    pub fn live_count(&self) -> usize {
        self.live().count()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    /// Successor lists for all nodes (index = node id; deleted nodes empty).
    /// Compatibility helper — hot paths use [`TrainingGraph::succ_csr`].
    pub fn successors(&self) -> Vec<Vec<NodeId>> {
        let mut succ = vec![Vec::new(); self.nodes.len()];
        for n in self.live() {
            for &i in &n.inputs {
                succ[i].push(n.id);
            }
        }
        succ
    }

    /// Cached CSR successor adjacency, built on first use after the last
    /// invalidation. See the `adj` field docs for the invalidation
    /// contract.
    pub fn succ_csr(&self) -> &SuccCsr {
        self.adj.get_or_init(|| SuccCsr::build(self))
    }

    /// Drop the cached adjacency. Called by `push` and the fusion
    /// rewrites; required after any direct edit of `nodes`.
    pub fn invalidate_adjacency(&mut self) {
        self.adj.take();
    }

    /// Kahn topological order over live nodes using `succ` as the
    /// adjacency. Errors with the id of a node on a cycle.
    fn topo_with(&self, succ: &SuccCsr) -> Result<Vec<NodeId>, GraphError> {
        let mut indeg = vec![0usize; self.nodes.len()];
        for n in self.live() {
            indeg[n.id] = n.inputs.len();
        }
        let mut queue: Vec<NodeId> =
            self.live().filter(|n| n.inputs.is_empty()).map(|n| n.id).collect();
        let mut order = Vec::with_capacity(self.live_count());
        let mut qi = 0;
        while qi < queue.len() {
            let u = queue[qi];
            qi += 1;
            order.push(u);
            for &v in succ.row(u) {
                let v = v as usize;
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        if order.len() != self.live_count() {
            let stuck = self
                .live()
                .find(|n| indeg[n.id] > 0)
                .map(|n| n.id)
                .unwrap_or(0);
            return Err(GraphError::Cycle(stuck));
        }
        Ok(order)
    }

    /// Kahn topological order over live nodes (cached adjacency). Errors
    /// with the id of a node on a cycle.
    pub fn topo_order(&self) -> Result<Vec<NodeId>, GraphError> {
        self.topo_with(self.succ_csr())
    }

    /// Full validation: arena ids, dangling inputs, acyclicity. As the
    /// integrity auditor it rebuilds the adjacency from scratch rather
    /// than trusting the cache (a stale cache is one of the corruptions
    /// it exists to catch).
    pub fn validate(&self) -> Result<(), GraphError> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.id != i {
                return Err(GraphError::IdMismatch(n.id, i));
            }
            if n.deleted {
                continue;
            }
            for &inp in &n.inputs {
                if inp >= self.nodes.len() || self.nodes[inp].deleted {
                    return Err(GraphError::DanglingInput(n.id, inp));
                }
            }
        }
        self.topo_with(&SuccCsr::build(self)).map(|_| ())
    }

    // ---- aggregate queries ----------------------------------------------------

    /// Ids of all live AllReduce instructions.
    pub fn allreduces(&self) -> Vec<NodeId> {
        self.live().filter(|n| n.kind == OpKind::AllReduce).map(|n| n.id).collect()
    }

    /// Ids of all live fusible computation ops.
    pub fn compute_ops(&self) -> Vec<NodeId> {
        self.live()
            .filter(|n| n.kind.is_fusible_compute() || n.kind == OpKind::Fused)
            .map(|n| n.id)
            .collect()
    }

    /// Total gradient bytes communicated per iteration (invariant under
    /// tensor fusion — a key property test).
    pub fn total_gradient_bytes(&self) -> f64 {
        self.live()
            .filter(|n| n.kind == OpKind::AllReduce)
            .map(|n| n.bytes_out)
            .sum()
    }

    /// Total computation FLOPs (grows only via duplicate fusion).
    pub fn total_flops(&self) -> f64 {
        self.live().map(|n| n.flops).sum()
    }

    /// Number of original computation ops represented (fused groups count
    /// their members; invariant under non-duplicate fusion).
    pub fn represented_ops(&self) -> usize {
        self.live()
            .map(|n| match &n.fused {
                Some(g) => g.ops.iter().filter(|o| !o.duplicated).count(),
                None => usize::from(n.kind != OpKind::AllReduce),
            })
            .sum()
    }

    /// Append a node, assigning the next id. Used by the builder and by the
    /// fusion transforms (fused nodes are appended, members tombstoned).
    pub fn push(&mut self, mut node: Node) -> NodeId {
        node.id = self.nodes.len();
        let id = node.id;
        self.nodes.push(node);
        self.invalidate_adjacency();
        id
    }

    /// Inference view: tombstone every backward, communication and
    /// optimizer instruction, leaving the forward pass (used for the
    /// single-device comparison, paper Fig. 8).
    pub fn forward_only(&self) -> TrainingGraph {
        let mut g = self.clone();
        g.name = format!("{}-fwd", g.name);
        for n in g.nodes.iter_mut() {
            if matches!(n.role, Role::Backward | Role::Comm | Role::Optimizer) {
                n.deleted = true;
            }
        }
        g.invalidate_adjacency();
        // Drop now-unconsumed parameters? No — parameters feed forward ops.
        debug_assert!(g.validate().is_ok());
        g
    }

    /// Approximate resident bytes of this graph (arena + per-node heap
    /// allocations). Used by the search to report candidate-arena memory;
    /// an estimate, not an allocator census.
    pub fn approx_bytes(&self) -> usize {
        let mut b = std::mem::size_of::<TrainingGraph>()
            + self.name.capacity()
            + self.nodes.capacity() * std::mem::size_of::<Node>();
        for n in &self.nodes {
            b += n.name.capacity()
                + (n.inputs.capacity() + n.orig_inputs.capacity() + n.ar_constituents.capacity())
                    * std::mem::size_of::<NodeId>()
                + n.shape.dims.capacity() * std::mem::size_of::<usize>();
            if let Some(g) = &n.fused {
                b += g.ops.capacity() * std::mem::size_of::<OrigOp>()
                    + g.edges.capacity() * std::mem::size_of::<(usize, usize)>();
            }
        }
        b
    }

    /// Deep structural fingerprint of the live graph, for dedup of search
    /// candidates.
    pub fn fingerprint(&self) -> u64 {
        let mut h = DefaultHasher::new();
        for n in self.live() {
            n.id.hash(&mut h);
            n.kind.name().hash(&mut h);
            n.inputs.hash(&mut h);
            if let Some(g) = &n.fused {
                g.signature().hash(&mut h);
            }
            n.ar_constituents.hash(&mut h);
            // Chunking is hashed only when active so that `None` and
            // `Some(count <= 1)` — semantically identical schedules —
            // dedup to the same candidate fingerprint.
            if n.chunk_count() >= 2 {
                n.chunk_count().hash(&mut h);
            }
            // Sharding likewise: hashed only when active, so unsharded
            // graphs fingerprint exactly as they did before the sharding
            // dimension existed (DESIGN.md §16 bit-identity contract).
            if n.is_sharded_collective() {
                1u8.hash(&mut h);
            }
        }
        h.finish()
    }

    /// True if any live AllReduce carries an active chunking descriptor —
    /// the simulator's gate between the (unchanged) whole-tensor event
    /// loop and the chunked dual-track loop (DESIGN.md §13).
    pub fn has_chunking(&self) -> bool {
        self.live().any(|n| n.kind == OpKind::AllReduce && n.chunk_count() >= 2)
    }

    /// True if any live collective carries an active sharding descriptor —
    /// together with [`TrainingGraph::has_chunking`] this gates the
    /// simulator's extended dual-track event loop; a graph with neither
    /// replays through today's whole-tensor loop bit-identically
    /// (DESIGN.md §16).
    pub fn has_sharding(&self) -> bool {
        self.live().any(|n| n.is_sharded_collective())
    }
}

#[cfg(test)]
mod tests {
    use super::builder::GraphBuilder;
    use super::*;

    fn tiny() -> TrainingGraph {
        // p -> mul -> relu -> grad(mul) -> allreduce -> apply
        let mut b = GraphBuilder::new("tiny", 4);
        let p = b.param("w", &[128, 128]);
        let m = b.compute(OpKind::MatMul, "mm", &[p, p], &[128, 128], Role::Forward);
        let r = b.compute(OpKind::Relu, "relu", &[m], &[128, 128], Role::Forward);
        let g = b.compute(OpKind::MatMul, "grad", &[r], &[128, 128], Role::Backward);
        let ar = b.allreduce("ar", g, &[128, 128]);
        b.optimizer_update("apply", &[ar, p]);
        b.finish()
    }

    #[test]
    fn build_and_validate() {
        let g = tiny();
        assert!(g.validate().is_ok());
        assert_eq!(g.allreduces().len(), 1);
        assert!(g.live_count() >= 6);
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = tiny();
        let order = g.topo_order().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; g.nodes.len()];
            for (i, &id) in order.iter().enumerate() {
                p[id] = i;
            }
            p
        };
        for n in g.live() {
            for &i in &n.inputs {
                assert!(pos[i] < pos[n.id], "{} before {}", i, n.id);
            }
        }
    }

    #[test]
    fn cycle_detected() {
        let mut g = tiny();
        // Introduce a cycle: first compute node consumes the last one.
        let last = g.nodes.len() - 1;
        g.nodes[1].inputs.push(last);
        assert!(matches!(g.validate(), Err(GraphError::Cycle(_))));
    }

    #[test]
    fn dangling_detected() {
        let mut g = tiny();
        let victim = g.nodes[2].inputs[0];
        g.nodes[victim].deleted = true;
        assert!(matches!(g.validate(), Err(GraphError::DanglingInput(_, _))));
    }

    #[test]
    fn fingerprint_stable_and_sensitive() {
        let a = tiny();
        let b = tiny();
        assert_eq!(a.fingerprint(), b.fingerprint());
        let mut c = tiny();
        c.nodes[2].deleted = true;
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn fused_group_signature_order_independent() {
        let op = |id: NodeId| OrigOp {
            orig_id: id,
            kind: OpKind::Mul,
            flops: 10.0,
            bytes_in: 8.0,
            bytes_out: 8.0,
            time_ms: 0.0,
            duplicated: false,
        };
        let g1 = FusedGroup { ops: vec![op(1), op(2)], edges: vec![(0, 1)] };
        let g2 = FusedGroup { ops: vec![op(2), op(1)], edges: vec![(1, 0)] };
        assert_eq!(g1.signature(), g2.signature());
        let g3 = FusedGroup { ops: vec![op(1), op(3)], edges: vec![(0, 1)] };
        assert_ne!(g1.signature(), g3.signature());
    }

    #[test]
    fn cost_signature_distinguishes_shapes() {
        let g = tiny();
        let a = g.nodes[1].cost_signature();
        let mut n2 = g.nodes[1].clone();
        n2.shape = Shape::new(&[64, 64]);
        assert_ne!(a, n2.cost_signature());
    }

    #[test]
    fn succ_csr_matches_successors() {
        let g = tiny();
        let csr = g.succ_csr();
        let succ = g.successors();
        for id in 0..g.nodes.len() {
            let row: Vec<NodeId> = csr.row(id).iter().map(|&v| v as NodeId).collect();
            assert_eq!(row, succ[id], "row {id}");
            assert_eq!(csr.out_degree(id), succ[id].len());
        }
    }

    #[test]
    fn succ_csr_invalidated_by_push() {
        let mut g = tiny();
        let before = g.succ_csr().targets.len();
        let src = g.nodes[2].id;
        let mut n = g.nodes[2].clone();
        n.inputs = vec![src];
        n.orig_inputs = vec![src];
        n.name = "extra".into();
        g.push(n);
        // Cache was dropped by push; the rebuilt CSR sees the new edge.
        assert_eq!(g.succ_csr().targets.len(), before + 1);
    }

    #[test]
    fn succ_csr_skips_deleted_consumers() {
        let mut g = tiny();
        let _ = g.succ_csr();
        g.nodes[3].deleted = true;
        g.invalidate_adjacency();
        let csr = g.succ_csr();
        assert!(csr.targets.iter().all(|&t| t != 3));
    }

    #[test]
    fn approx_bytes_positive_and_grows() {
        let g = tiny();
        let b = g.approx_bytes();
        assert!(b > g.nodes.len() * std::mem::size_of::<Node>());
        let mut g2 = g.clone();
        let n = g2.nodes[1].clone();
        g2.push(n);
        assert!(g2.approx_bytes() > b);
    }

    #[test]
    fn node_flags_epoch_reset() {
        let mut f = NodeFlags::new();
        f.reset(4);
        assert!(!f.is_marked(0));
        f.mark(0);
        f.mark(3);
        assert!(f.is_marked(0) && f.is_marked(3) && !f.is_marked(1));
        // Reset clears without touching the backing store.
        f.reset(4);
        assert!(!f.is_marked(0) && !f.is_marked(3));
        // Growing keeps old-capacity slots unmarked.
        f.mark(1);
        f.reset(8);
        assert!((0..8).all(|i| !f.is_marked(i)));
    }

    #[test]
    fn chunk_bytes_conserve_total_exactly() {
        for k in 1..=9u32 {
            for total in [0.0, 1.0, 7.0, 4096.0, 65536.0 + 3.0] {
                let parts = ChunkSpec::new(k).chunk_bytes(total);
                assert_eq!(parts.len(), k as usize);
                assert_eq!(parts.iter().sum::<f64>(), total, "k={k} total={total}");
                // Chunks differ by at most one byte.
                let max = parts.iter().cloned().fold(0.0, f64::max);
                let min = parts.iter().cloned().fold(f64::INFINITY, f64::min);
                assert!(max - min <= 1.0);
            }
        }
    }

    #[test]
    fn chunk_count_one_is_canonically_unchunked() {
        let base = tiny();
        let ar = base.allreduces()[0];
        let mut one = base.clone();
        one.nodes[ar].chunk = Some(ChunkSpec::new(1));
        // count <= 1 is identical to no descriptor at all.
        assert_eq!(base.fingerprint(), one.fingerprint());
        assert!(!one.has_chunking());
        assert_eq!(one.nodes[ar].chunk_count(), 1);
        let mut four = base.clone();
        four.nodes[ar].chunk = Some(ChunkSpec::new(4));
        assert_ne!(base.fingerprint(), four.fingerprint());
        assert!(four.has_chunking());
        assert_eq!(four.nodes[ar].chunk_count(), 4);
    }

    #[test]
    fn shard_bytes_conserve_total_exactly() {
        for w in 1..=9usize {
            for total in [0.0, 1.0, 7.0, 4096.0, 65536.0 + 3.0] {
                let parts = ShardSpec::shard_bytes(total, w);
                assert_eq!(parts.len(), w);
                assert_eq!(parts.iter().sum::<f64>(), total, "w={w} total={total}");
                // Shards differ by at most one byte.
                let max = parts.iter().cloned().fold(0.0, f64::max);
                let min = parts.iter().cloned().fold(f64::INFINITY, f64::min);
                assert!(max - min <= 1.0);
            }
        }
    }

    #[test]
    fn shard_kind_allreduce_is_canonically_unsharded() {
        let base = tiny();
        let ar = base.allreduces()[0];
        let mut inert = base.clone();
        inert.nodes[ar].shard = Some(ShardSpec::new(CollectiveKind::AllReduce));
        // kind = AllReduce is identical to no descriptor at all.
        assert_eq!(base.fingerprint(), inert.fingerprint());
        assert!(!inert.has_sharding());
        assert_eq!(inert.nodes[ar].shard_kind(), CollectiveKind::AllReduce);
        let mut sharded = base.clone();
        sharded.nodes[ar].shard =
            Some(ShardSpec::new(CollectiveKind::ReduceScatterAllGather));
        assert_ne!(base.fingerprint(), sharded.fingerprint());
        assert!(sharded.has_sharding());
        assert!(sharded.nodes[ar].is_sharded_collective());
    }

    #[test]
    fn placement_state_machine_matches_commfuser_model() {
        assert_eq!(ShardSpec::placement_after(0), Placement::Partial);
        assert_eq!(ShardSpec::placement_after(1), Placement::Sharded);
        assert_eq!(ShardSpec::placement_after(2), Placement::Sharded);
        assert_eq!(ShardSpec::placement_after(3), Placement::Replicated);
        assert_eq!(Placement::OnDemand.name(), "ondemand");
        assert_eq!(CollectiveKind::from_name("rs_ag"),
            Some(CollectiveKind::ReduceScatterAllGather));
        assert_eq!(CollectiveKind::from_name("ar"), Some(CollectiveKind::AllReduce));
        assert_eq!(CollectiveKind::from_name("x"), None);
    }

    #[test]
    fn represented_ops_counts_members() {
        let g = tiny();
        let before = g.represented_ops();
        assert!(before > 0);
        assert_eq!(g.total_gradient_bytes(), 128.0 * 128.0 * 4.0);
    }
}
