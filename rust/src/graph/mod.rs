//! HLO-like intermediate representation of one training iteration.
//!
//! A [`TrainingGraph`] is the unit the whole system operates on: the model
//! zoo builds one, the profiler annotates it, the fusion transforms rewrite
//! it, the simulator schedules it, and the search explores the space of its
//! rewrites. It corresponds to the paper's "HLO module of the whole DNN
//! model" (DisCo §3.1): forward ops, backward ops, AllReduce instructions
//! for every gradient tensor, and optimizer-update ops.
//!
//! Nodes are stored in an arena (`Vec<Node>`) with tombstones: fusion
//! transforms mark absorbed nodes `deleted` rather than re-indexing, so a
//! candidate rewrite is a cheap clone + local edits (important for the
//! search hot path).

pub mod op;
pub mod shape;
pub mod builder;
pub mod serial;
pub mod hlo_import;

pub use op::{OpKind, PatternClass};
pub use shape::{DType, Shape};

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};

/// Index of a node within its graph's arena.
pub type NodeId = usize;

/// Which phase of the training iteration an op belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Role {
    Forward,
    Backward,
    Optimizer,
    Comm,
    Param,
}

impl Role {
    pub fn name(self) -> &'static str {
        match self {
            Role::Forward => "fwd",
            Role::Backward => "bwd",
            Role::Optimizer => "opt",
            Role::Comm => "comm",
            Role::Param => "param",
        }
    }

    pub fn from_name(s: &str) -> Option<Role> {
        match s {
            "fwd" => Some(Role::Forward),
            "bwd" => Some(Role::Backward),
            "opt" => Some(Role::Optimizer),
            "comm" => Some(Role::Comm),
            "param" => Some(Role::Param),
            _ => None,
        }
    }
}

/// Descriptor of an original (pre-fusion) op retained inside a fused group.
/// This is exactly the per-node feature record the GNN estimator consumes
/// (paper §4.3.1: op type, input/output sizes, profiled execution time).
#[derive(Debug, Clone, PartialEq)]
pub struct OrigOp {
    /// Node id in the *original* (unfused) graph — stable identity.
    pub orig_id: NodeId,
    pub kind: OpKind,
    pub flops: f64,
    pub bytes_in: f64,
    pub bytes_out: f64,
    /// Profiled single-op execution time in ms (0 until profiled).
    pub time_ms: f64,
    /// True if this op instance is a duplicate-fusion replica whose compute
    /// is re-paid inside the group.
    pub duplicated: bool,
}

/// The subgraph of original ops inside a fused computation op.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FusedGroup {
    pub ops: Vec<OrigOp>,
    /// Directed edges (producer index, consumer index) into `ops`.
    pub edges: Vec<(usize, usize)>,
}

impl FusedGroup {
    /// Deterministic signature for estimator caching: same member ops (by
    /// original id + duplication flag) and same internal wiring → same cost.
    pub fn signature(&self) -> u64 {
        let mut h = DefaultHasher::new();
        // Order-independent over ops: sort keys first.
        let mut keys: Vec<(NodeId, bool)> =
            self.ops.iter().map(|o| (o.orig_id, o.duplicated)).collect();
        keys.sort_unstable();
        keys.hash(&mut h);
        let mut edges: Vec<(NodeId, NodeId)> = self
            .edges
            .iter()
            .map(|&(a, b)| (self.ops[a].orig_id, self.ops[b].orig_id))
            .collect();
        edges.sort_unstable();
        edges.hash(&mut h);
        h.finish()
    }

    pub fn total_flops(&self) -> f64 {
        self.ops.iter().map(|o| o.flops).sum()
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

/// One instruction of the training graph.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub kind: OpKind,
    pub role: Role,
    /// Producers of this node's operands (live node ids). Fusion
    /// transforms redirect these when consumers are rewired.
    pub inputs: Vec<NodeId>,
    /// The inputs this instruction had when first created — never mutated
    /// by rewrites. Fused-group internal wiring is derived from these.
    pub orig_inputs: Vec<NodeId>,
    /// Primary output shape.
    pub shape: Shape,
    pub dtype: DType,
    /// Floating-point operations performed by this op.
    pub flops: f64,
    /// Bytes read from device memory (operand bytes).
    pub bytes_in: f64,
    /// Bytes written to device memory (result bytes).
    pub bytes_out: f64,
    /// For `OpKind::Fused`: the internal subgraph of original ops.
    pub fused: Option<FusedGroup>,
    /// For `OpKind::AllReduce`: ids of the *original* AllReduce instructions
    /// merged into this one (singleton when unfused). Used for neighbor
    /// discovery and byte accounting in tensor fusion.
    pub ar_constituents: Vec<NodeId>,
    /// Tombstone: true once absorbed by a fusion transform.
    pub deleted: bool,
}

impl Node {
    /// Gradient-tensor bytes carried by an AllReduce node.
    pub fn tensor_bytes(&self) -> f64 {
        debug_assert_eq!(self.kind, OpKind::AllReduce);
        self.bytes_out
    }

    /// Signature used as an estimator cache key. Unfused ops key on
    /// (kind, shape, dtype); fused ops key on their group signature —
    /// the paper's "indexed by op_code and input shape" (§4.2).
    pub fn cost_signature(&self) -> u64 {
        let mut h = DefaultHasher::new();
        match &self.fused {
            Some(g) => {
                1u8.hash(&mut h);
                g.signature().hash(&mut h);
            }
            None => {
                0u8.hash(&mut h);
                self.kind.name().hash(&mut h);
                self.shape.dims.hash(&mut h);
                self.dtype.name().hash(&mut h);
                (self.flops.to_bits(), self.bytes_in.to_bits(), self.bytes_out.to_bits())
                    .hash(&mut h);
            }
        }
        h.finish()
    }
}

/// Validation failures for a graph (used by the search's validity check).
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum GraphError {
    #[error("node {0} references missing/deleted input {1}")]
    DanglingInput(NodeId, NodeId),
    #[error("graph contains a cycle involving node {0}")]
    Cycle(NodeId),
    #[error("node {0} ({1}) of kind {2} may not be fused")]
    InvalidFusion(NodeId, String, String),
    #[error("node {0} id does not match arena index {1}")]
    IdMismatch(NodeId, usize),
}

/// A whole training-iteration graph for one worker replica, plus the
/// data-parallel context (worker count) its AllReduces span.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainingGraph {
    pub name: String,
    pub nodes: Vec<Node>,
    /// Number of data-parallel workers (devices) the AllReduces span.
    pub num_workers: usize,
}

impl TrainingGraph {
    pub fn new(name: &str, num_workers: usize) -> TrainingGraph {
        TrainingGraph { name: name.to_string(), nodes: Vec::new(), num_workers }
    }

    // ---- structure access ---------------------------------------------------

    /// Live (non-tombstoned) nodes.
    pub fn live(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(|n| !n.deleted)
    }

    pub fn live_count(&self) -> usize {
        self.live().count()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    /// Successor lists for all nodes (index = node id; deleted nodes empty).
    pub fn successors(&self) -> Vec<Vec<NodeId>> {
        let mut succ = vec![Vec::new(); self.nodes.len()];
        for n in self.live() {
            for &i in &n.inputs {
                succ[i].push(n.id);
            }
        }
        succ
    }

    /// Kahn topological order over live nodes. Errors with the id of a node
    /// on a cycle.
    pub fn topo_order(&self) -> Result<Vec<NodeId>, GraphError> {
        let mut indeg = vec![0usize; self.nodes.len()];
        let succ = self.successors();
        for n in self.live() {
            indeg[n.id] = n.inputs.len();
        }
        let mut queue: Vec<NodeId> =
            self.live().filter(|n| n.inputs.is_empty()).map(|n| n.id).collect();
        let mut order = Vec::with_capacity(self.live_count());
        let mut qi = 0;
        while qi < queue.len() {
            let u = queue[qi];
            qi += 1;
            order.push(u);
            for &v in &succ[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        if order.len() != self.live_count() {
            let stuck = self
                .live()
                .find(|n| indeg[n.id] > 0)
                .map(|n| n.id)
                .unwrap_or(0);
            return Err(GraphError::Cycle(stuck));
        }
        Ok(order)
    }

    /// Full validation: arena ids, dangling inputs, acyclicity.
    pub fn validate(&self) -> Result<(), GraphError> {
        for (i, n) in self.nodes.iter().enumerate() {
            if n.id != i {
                return Err(GraphError::IdMismatch(n.id, i));
            }
            if n.deleted {
                continue;
            }
            for &inp in &n.inputs {
                if inp >= self.nodes.len() || self.nodes[inp].deleted {
                    return Err(GraphError::DanglingInput(n.id, inp));
                }
            }
        }
        self.topo_order().map(|_| ())
    }

    // ---- aggregate queries ----------------------------------------------------

    /// Ids of all live AllReduce instructions.
    pub fn allreduces(&self) -> Vec<NodeId> {
        self.live().filter(|n| n.kind == OpKind::AllReduce).map(|n| n.id).collect()
    }

    /// Ids of all live fusible computation ops.
    pub fn compute_ops(&self) -> Vec<NodeId> {
        self.live()
            .filter(|n| n.kind.is_fusible_compute() || n.kind == OpKind::Fused)
            .map(|n| n.id)
            .collect()
    }

    /// Total gradient bytes communicated per iteration (invariant under
    /// tensor fusion — a key property test).
    pub fn total_gradient_bytes(&self) -> f64 {
        self.live()
            .filter(|n| n.kind == OpKind::AllReduce)
            .map(|n| n.bytes_out)
            .sum()
    }

    /// Total computation FLOPs (grows only via duplicate fusion).
    pub fn total_flops(&self) -> f64 {
        self.live().map(|n| n.flops).sum()
    }

    /// Number of original computation ops represented (fused groups count
    /// their members; invariant under non-duplicate fusion).
    pub fn represented_ops(&self) -> usize {
        self.live()
            .map(|n| match &n.fused {
                Some(g) => g.ops.iter().filter(|o| !o.duplicated).count(),
                None => usize::from(n.kind != OpKind::AllReduce),
            })
            .sum()
    }

    /// Append a node, assigning the next id. Used by the builder and by the
    /// fusion transforms (fused nodes are appended, members tombstoned).
    pub fn push(&mut self, mut node: Node) -> NodeId {
        node.id = self.nodes.len();
        let id = node.id;
        self.nodes.push(node);
        id
    }

    /// Inference view: tombstone every backward, communication and
    /// optimizer instruction, leaving the forward pass (used for the
    /// single-device comparison, paper Fig. 8).
    pub fn forward_only(&self) -> TrainingGraph {
        let mut g = self.clone();
        g.name = format!("{}-fwd", g.name);
        for n in g.nodes.iter_mut() {
            if matches!(n.role, Role::Backward | Role::Comm | Role::Optimizer) {
                n.deleted = true;
            }
        }
        // Drop now-unconsumed parameters? No — parameters feed forward ops.
        debug_assert!(g.validate().is_ok());
        g
    }

    /// Deep structural fingerprint of the live graph, for dedup of search
    /// candidates.
    pub fn fingerprint(&self) -> u64 {
        let mut h = DefaultHasher::new();
        for n in self.live() {
            n.id.hash(&mut h);
            n.kind.name().hash(&mut h);
            n.inputs.hash(&mut h);
            if let Some(g) = &n.fused {
                g.signature().hash(&mut h);
            }
            n.ar_constituents.hash(&mut h);
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::builder::GraphBuilder;
    use super::*;

    fn tiny() -> TrainingGraph {
        // p -> mul -> relu -> grad(mul) -> allreduce -> apply
        let mut b = GraphBuilder::new("tiny", 4);
        let p = b.param("w", &[128, 128]);
        let m = b.compute(OpKind::MatMul, "mm", &[p, p], &[128, 128], Role::Forward);
        let r = b.compute(OpKind::Relu, "relu", &[m], &[128, 128], Role::Forward);
        let g = b.compute(OpKind::MatMul, "grad", &[r], &[128, 128], Role::Backward);
        let ar = b.allreduce("ar", g, &[128, 128]);
        b.optimizer_update("apply", &[ar, p]);
        b.finish()
    }

    #[test]
    fn build_and_validate() {
        let g = tiny();
        assert!(g.validate().is_ok());
        assert_eq!(g.allreduces().len(), 1);
        assert!(g.live_count() >= 6);
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = tiny();
        let order = g.topo_order().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; g.nodes.len()];
            for (i, &id) in order.iter().enumerate() {
                p[id] = i;
            }
            p
        };
        for n in g.live() {
            for &i in &n.inputs {
                assert!(pos[i] < pos[n.id], "{} before {}", i, n.id);
            }
        }
    }

    #[test]
    fn cycle_detected() {
        let mut g = tiny();
        // Introduce a cycle: first compute node consumes the last one.
        let last = g.nodes.len() - 1;
        g.nodes[1].inputs.push(last);
        assert!(matches!(g.validate(), Err(GraphError::Cycle(_))));
    }

    #[test]
    fn dangling_detected() {
        let mut g = tiny();
        let victim = g.nodes[2].inputs[0];
        g.nodes[victim].deleted = true;
        assert!(matches!(g.validate(), Err(GraphError::DanglingInput(_, _))));
    }

    #[test]
    fn fingerprint_stable_and_sensitive() {
        let a = tiny();
        let b = tiny();
        assert_eq!(a.fingerprint(), b.fingerprint());
        let mut c = tiny();
        c.nodes[2].deleted = true;
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn fused_group_signature_order_independent() {
        let op = |id: NodeId| OrigOp {
            orig_id: id,
            kind: OpKind::Mul,
            flops: 10.0,
            bytes_in: 8.0,
            bytes_out: 8.0,
            time_ms: 0.0,
            duplicated: false,
        };
        let g1 = FusedGroup { ops: vec![op(1), op(2)], edges: vec![(0, 1)] };
        let g2 = FusedGroup { ops: vec![op(2), op(1)], edges: vec![(1, 0)] };
        assert_eq!(g1.signature(), g2.signature());
        let g3 = FusedGroup { ops: vec![op(1), op(3)], edges: vec![(0, 1)] };
        assert_ne!(g1.signature(), g3.signature());
    }

    #[test]
    fn cost_signature_distinguishes_shapes() {
        let g = tiny();
        let a = g.nodes[1].cost_signature();
        let mut n2 = g.nodes[1].clone();
        n2.shape = Shape::new(&[64, 64]);
        assert_ne!(a, n2.cost_signature());
    }

    #[test]
    fn represented_ops_counts_members() {
        let g = tiny();
        let before = g.represented_ops();
        assert!(before > 0);
        assert_eq!(g.total_gradient_bytes(), 128.0 * 128.0 * 4.0);
    }
}
