//! Fusion transforms — the rewrite rules the search explores (paper §3.2).
//!
//! Three rewrites over a [`TrainingGraph`]:
//!
//! * **Non-duplicate op fusion** ([`fuse_ops`] with
//!   [`FusionKind::NonDuplicate`], paper Fig. 1(ii)): predecessor `p` is
//!   absorbed into successor `s`; `p`'s other consumers are redirected to
//!   the fused op, so `p`'s output only becomes available when the whole
//!   fused kernel finishes — this is the communication-delay effect the
//!   paper is built around.
//! * **Duplicate op fusion** ([`FusionKind::Duplicate`], Fig. 1(iii)):
//!   `p` is copied into the fused kernel (compute re-paid) *and* stays live
//!   outside, so its other consumers — in particular AllReduces — get its
//!   output early.
//! * **AllReduce tensor fusion** ([`fuse_allreduce`]): two neighbouring
//!   AllReduce instructions are combined; the fused instruction starts only
//!   once *all* constituent gradients are produced, but pays the
//!   per-AllReduce negotiation overhead once.
//!
//! Nodes are tombstoned, never re-indexed, so `OrigOp::orig_id` always
//! refers to the original instruction in the same arena — fused-group
//! internal wiring is re-derivable from the original graph at any time.
//!
//! A fourth, *in-place* rewrite ([`set_chunks`]) chunks an AllReduce so the
//! simulator can stream it: no nodes are created or tombstoned and no edge
//! moves, only the instruction's [`ChunkSpec`] changes. Tensor fusion
//! resets chunking on the fused AllReduce (it is a new collective); the
//! search re-chunks it explicitly when that wins.
//!
//! A fifth in-place rewrite ([`set_sharding`]) switches a collective
//! between DDP all-reduce and ZeRO/FSDP reduce-scatter + all-gather
//! ([`ShardSpec`], DESIGN.md §16). Legality: only same-kind collectives
//! tensor-fuse, a sharded collective is never chunked (activating one
//! resets the other), and sharding requires every consumer to be an
//! optimizer update (the phase split reorders the optimizer step against
//! the parameter re-replication, which is only sound when nothing else
//! reads the reduced gradient).

use crate::graph::{
    ChunkSpec, CollectiveKind, FusedGroup, Node, NodeId, OpKind, OrigOp, Role, ShardSpec,
    TrainingGraph,
};

/// Upper bound on chunks per collective the vocabulary will propose. Keeps
/// the per-AR branching factor bounded and the per-chunk transfer above the
/// latency floor where streaming stops paying.
pub const MAX_CHUNKS: u32 = 32;

/// A chunking is only legal if every chunk carries at least this many
/// bytes — below this the per-chunk fixed costs dominate and the schedule
/// space just gains noise.
pub const MIN_CHUNK_BYTES: f64 = 1024.0;

/// Op-fusion flavour (paper Fig. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusionKind {
    NonDuplicate,
    Duplicate,
}

/// Why a rewrite was rejected. Invalid candidates are simply skipped by the
/// search (Alg. 1's `if H' is valid` check).
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum FusionError {
    #[error("node {0} is not a live computation op")]
    NotCompute(NodeId),
    #[error("{0} is not a predecessor of {1}")]
    NotPredecessor(NodeId, NodeId),
    #[error("non-duplicate fusion of {0} into {1} would create a cycle")]
    WouldCycle(NodeId, NodeId),
    #[error("node {0} is not a live AllReduce")]
    NotAllReduce(NodeId),
    #[error("AllReduce {0} and {1} are not neighbours")]
    NotNeighbors(NodeId, NodeId),
    #[error("cannot fuse a node with itself")]
    SelfFusion,
    #[error("chunking AllReduce {0} into {1} chunks is illegal: {2}")]
    BadChunking(NodeId, u32, &'static str),
    #[error("sharding collective {0} is illegal: {1}")]
    BadSharding(NodeId, &'static str),
    #[error("collectives {0} and {1} have different collective kinds")]
    MixedCollectiveKinds(NodeId, NodeId),
}

/// Singleton fused-group view of a (possibly already fused) compute node.
pub fn group_of(node: &Node) -> FusedGroup {
    match &node.fused {
        Some(g) => g.clone(),
        None => FusedGroup {
            ops: vec![OrigOp {
                orig_id: node.id,
                kind: node.kind,
                flops: node.flops,
                bytes_in: node.bytes_in,
                bytes_out: node.bytes_out,
                time_ms: 0.0,
                duplicated: false,
            }],
            edges: vec![],
        },
    }
}

fn is_live_compute(g: &TrainingGraph, id: NodeId) -> bool {
    id < g.nodes.len() && !g.nodes[id].deleted && {
        let k = g.nodes[id].kind;
        k.is_fusible_compute() || k == OpKind::Fused
    }
}

/// Is there a path `from ⇝ to` over live nodes, excluding the direct edge
/// `from → to`? Used for the non-duplicate-fusion cycle check.
///
/// Perf note (§Perf iteration 1): walks *backwards* from `to` along
/// `inputs`, so no successor adjacency needs to be materialized — this
/// took `fuse_ops` on the full transformer graph from 167 µs to ~40 µs.
fn has_indirect_path(g: &TrainingGraph, from: NodeId, to: NodeId) -> bool {
    // Seed with `to`'s inputs, skipping the direct `from` edge.
    let mut stack: Vec<NodeId> =
        g.nodes[to].inputs.iter().copied().filter(|&i| i != from).collect();
    let mut visited = vec![false; g.nodes.len()];
    while let Some(u) = stack.pop() {
        if u == from {
            return true;
        }
        if visited[u] {
            continue;
        }
        visited[u] = true;
        for &v in &g.nodes[u].inputs {
            if !visited[v] {
                stack.push(v);
            }
        }
    }
    false
}

/// Re-derive intra-group edges from the original arena wiring: an edge
/// exists where one member's original instruction consumed another's.
fn derive_edges(g: &TrainingGraph, ops: &[OrigOp]) -> Vec<(usize, usize)> {
    let mut edges = Vec::new();
    for (j, b) in ops.iter().enumerate() {
        let orig_inputs = &g.nodes[b.orig_id].orig_inputs;
        for (i, a) in ops.iter().enumerate() {
            if i != j && orig_inputs.contains(&a.orig_id) {
                edges.push((i, j));
            }
        }
    }
    edges
}

/// External input bytes of a fused node: each distinct external producer is
/// read once (on-chip reuse inside the kernel).
fn external_input_bytes(g: &TrainingGraph, inputs: &[NodeId]) -> f64 {
    inputs.iter().map(|&i| g.nodes[i].bytes_out).sum()
}

/// Collapse duplicate references to the newly-created `fused` node in a
/// rewritten consumer's input list (a consumer of both rewrite operands
/// lists the fused node twice after redirection), preserving every other
/// operand — including pre-existing legitimate duplicates like x·x, even
/// when the same consumer was redirected. A rewrite must not edit edges
/// it didn't create: the delta simulator relies on [`FusionEffects`]
/// plus the fused node's input list covering every node whose adjacency
/// changed, and an unrelated operand's consumer count is outside that
/// set.
fn dedup_fused_ref_in_place(ins: &mut Vec<NodeId>, fused: NodeId) {
    let mut seen = false;
    ins.retain(|&i| {
        if i == fused {
            if seen {
                return false;
            }
            seen = true;
        }
        true
    });
}

/// What a successful rewrite did to the graph, beyond creating the fused
/// node — enough for incremental maintenance of derived state (the
/// search's [`CandidateSet`], the delta simulator's mutation frontier)
/// without rescanning the graph.
#[derive(Debug, Clone, PartialEq)]
pub struct FusionEffects {
    /// Id of the new fused node.
    pub fused: NodeId,
    /// Consumers whose input list was redirected to `fused` (deduped, in
    /// ascending node-id order) — exactly the consumers of `fused`.
    pub redirected: Vec<NodeId>,
    /// Whether the predecessor was tombstoned (false only for duplicate
    /// fusion that kept the replica live; always true for AR fusion,
    /// which tombstones both constituents).
    pub pred_deleted: bool,
}

impl FusionEffects {
    /// Append every node this rewrite structurally touched to `out`: the
    /// fused node, the consumers whose inputs were redirected, and the
    /// fused node's inputs (their consumer sets — and hence simulator
    /// refcounts — changed). Together with the mutation's operands
    /// (pred/succ or a/b, which the caller records anyway) this is the
    /// complete set of nodes whose scheduler state can differ from the
    /// parent graph's — the *mutation frontier* consumed by
    /// [`crate::sim::simulate_delta`]. `g` must be the graph state right
    /// after the rewrite (the fused node's input list is read from it).
    pub fn extend_frontier(&self, g: &TrainingGraph, out: &mut Vec<NodeId>) {
        out.push(self.fused);
        out.extend_from_slice(&self.redirected);
        out.extend_from_slice(&g.nodes[self.fused].inputs);
    }
}

/// Fuse predecessor `pred` into successor `succ`. Returns the id of the new
/// fused node. See module docs for semantics of the two kinds.
pub fn fuse_ops(
    g: &mut TrainingGraph,
    pred: NodeId,
    succ: NodeId,
    kind: FusionKind,
) -> Result<NodeId, FusionError> {
    fuse_ops_explain(g, pred, succ, kind).map(|fx| fx.fused)
}

/// [`fuse_ops`] returning the full [`FusionEffects`] record.
pub fn fuse_ops_explain(
    g: &mut TrainingGraph,
    pred: NodeId,
    succ: NodeId,
    kind: FusionKind,
) -> Result<FusionEffects, FusionError> {
    if pred == succ {
        return Err(FusionError::SelfFusion);
    }
    if !is_live_compute(g, pred) {
        return Err(FusionError::NotCompute(pred));
    }
    if !is_live_compute(g, succ) {
        return Err(FusionError::NotCompute(succ));
    }
    if !g.nodes[succ].inputs.contains(&pred) {
        return Err(FusionError::NotPredecessor(pred, succ));
    }
    // Single scan instead of materializing full successor lists (§Perf).
    let pred_has_other_consumers = g
        .live()
        .any(|n| n.id != succ && n.inputs.contains(&pred));
    // Duplicate fusion of a single-consumer pred degenerates to
    // non-duplicate fusion: there is no second consumer to serve early, so
    // nothing is actually recomputed. Normalize so the cost accounting
    // (duplicated flags, represented-op count) stays truthful.
    let kind = if kind == FusionKind::Duplicate && !pred_has_other_consumers {
        FusionKind::NonDuplicate
    } else {
        kind
    };
    if kind == FusionKind::NonDuplicate && has_indirect_path(g, pred, succ) {
        return Err(FusionError::WouldCycle(pred, succ));
    }

    // --- merged member set -------------------------------------------------
    let mut ops = group_of(&g.nodes[pred]).ops;
    if kind == FusionKind::Duplicate {
        for o in &mut ops {
            o.duplicated = true;
        }
    }
    ops.extend(group_of(&g.nodes[succ]).ops);
    let edges = derive_edges(g, &ops);
    let group = FusedGroup { ops, edges };

    // --- node-level wiring ----------------------------------------------------
    // External inputs: union of both nodes' inputs, minus pred itself
    // (internalized), minus anything the group now produces.
    let mut inputs: Vec<NodeId> = Vec::new();
    let keep_pred_live = kind == FusionKind::Duplicate && pred_has_other_consumers;
    for &i in g.nodes[pred].inputs.iter().chain(g.nodes[succ].inputs.iter()) {
        if i != pred && i != succ && !inputs.contains(&i) {
            inputs.push(i);
        }
    }

    let (p_flops, p_bytes_out, p_role) =
        (g.nodes[pred].flops, g.nodes[pred].bytes_out, g.nodes[pred].role);
    let (s_flops, s_bytes_out, s_role, s_shape, s_dtype) = (
        g.nodes[succ].flops,
        g.nodes[succ].bytes_out,
        g.nodes[succ].role,
        g.nodes[succ].shape.clone(),
        g.nodes[succ].dtype,
    );

    // Output bytes: the successor's result, plus — for non-duplicate fusion
    // with external consumers of pred — pred's result, which the fused
    // kernel must still materialize for them.
    let extra_out = if kind == FusionKind::NonDuplicate && pred_has_other_consumers {
        p_bytes_out
    } else {
        0.0
    };
    let role = if p_role == Role::Backward || s_role == Role::Backward {
        Role::Backward
    } else {
        s_role
    };
    let bytes_in = external_input_bytes(g, &inputs);

    let fused_id = g.push(Node {
        id: 0,
        name: format!("fused({},{})", g.nodes[pred].name, g.nodes[succ].name),
        kind: OpKind::Fused,
        role,
        orig_inputs: inputs.clone(),
        inputs,
        shape: s_shape,
        dtype: s_dtype,
        flops: p_flops + s_flops,
        bytes_in,
        bytes_out: s_bytes_out + extra_out,
        fused: Some(group),
        ar_constituents: Vec::new(),
        chunk: None,
        shard: None,
        deleted: false,
    });

    // Redirect consumers.
    let mut redirected: Vec<NodeId> = Vec::new();
    for n in 0..fused_id {
        if g.nodes[n].deleted {
            continue;
        }
        let redirect_pred = kind == FusionKind::NonDuplicate && n != succ;
        let mut hit = false;
        for idx in 0..g.nodes[n].inputs.len() {
            let i = g.nodes[n].inputs[idx];
            if i == succ || (i == pred && redirect_pred) {
                g.nodes[n].inputs[idx] = fused_id;
                hit = true;
            }
        }
        if hit {
            redirected.push(n);
            // A rewritten consumer may now list the fused node twice (it
            // consumed both pred and succ); collapse that — and only that
            // — to keep byte accounting sane (see dedup_fused_ref_in_place
            // for why no other operand may be touched).
            dedup_fused_ref_in_place(&mut g.nodes[n].inputs, fused_id);
        }
    }

    // Tombstones.
    g.nodes[succ].deleted = true;
    let pred_deleted = kind == FusionKind::NonDuplicate || !keep_pred_live;
    if pred_deleted {
        g.nodes[pred].deleted = true;
    }

    g.invalidate_adjacency();
    debug_assert!(g.validate().is_ok(), "fusion broke the graph");
    Ok(FusionEffects { fused: fused_id, redirected, pred_deleted })
}

/// Producer compute ops of an AllReduce (its live inputs).
fn producers(g: &TrainingGraph, ar: NodeId) -> Vec<NodeId> {
    g.nodes[ar].inputs.clone()
}

/// The one-hop-up neighbourhood of an AllReduce: its gradient producers
/// plus their direct inputs. Weight-gradient ops branching off the same
/// (or adjacent) step of the backward chain share this neighbourhood.
fn ar_vicinity(g: &TrainingGraph, ar: NodeId) -> Vec<NodeId> {
    let mut v = producers(g, ar);
    let mut extra = Vec::new();
    for &p in &v {
        for &i in &g.nodes[p].inputs {
            if !g.nodes[i].deleted {
                extra.push(i);
            }
        }
    }
    v.extend(extra);
    v.sort_unstable();
    v.dedup();
    v
}

/// Are two AllReduce instructions neighbours? (Paper §3.2: the gradient
/// tensors are produced by BP ops that are successors/predecessors of each
/// other.) In BP graphs weight-gradient ops are *siblings* hanging off the
/// backward activation chain, so we treat gradients as neighbours when
/// their producers' one-hop neighbourhoods intersect or are connected by
/// a direct edge — which is exactly "adjacent steps of backprop".
pub fn are_ar_neighbors(g: &TrainingGraph, a: NodeId, b: NodeId) -> bool {
    let va = ar_vicinity(g, a);
    let vb = ar_vicinity(g, b);
    for &x in &va {
        if vb.binary_search(&x).is_ok() {
            return true;
        }
        for &y in &vb {
            if g.nodes[x].inputs.contains(&y) || g.nodes[y].inputs.contains(&x) {
                return true;
            }
        }
    }
    false
}

/// All neighbour AllReduces of `ar` among live AllReduce instructions.
pub fn ar_neighbors(g: &TrainingGraph, ar: NodeId) -> Vec<NodeId> {
    g.allreduces()
        .into_iter()
        .filter(|&other| other != ar && are_ar_neighbors(g, ar, other))
        .collect()
}

/// Combine two neighbouring AllReduce instructions into one fused AllReduce
/// carrying the concatenated gradient tensor. Returns the new node id.
pub fn fuse_allreduce(
    g: &mut TrainingGraph,
    a: NodeId,
    b: NodeId,
) -> Result<NodeId, FusionError> {
    fuse_allreduce_explain(g, a, b).map(|fx| fx.fused)
}

/// [`fuse_allreduce`] returning the full [`FusionEffects`] record (both
/// constituents are tombstoned; `redirected` holds the optimizer updates
/// rewired onto the fused instruction).
pub fn fuse_allreduce_explain(
    g: &mut TrainingGraph,
    a: NodeId,
    b: NodeId,
) -> Result<FusionEffects, FusionError> {
    if a == b {
        return Err(FusionError::SelfFusion);
    }
    for &x in &[a, b] {
        if x >= g.nodes.len() || g.nodes[x].deleted || g.nodes[x].kind != OpKind::AllReduce {
            return Err(FusionError::NotAllReduce(x));
        }
    }
    if !are_ar_neighbors(g, a, b) {
        return Err(FusionError::NotNeighbors(a, b));
    }
    // Only same-kind collectives fuse (DESIGN.md §16): a reduce-scatter
    // phase and a whole all-reduce have different completion semantics,
    // so a mixed fusion has no single collective implementing it.
    if g.nodes[a].shard_kind() != g.nodes[b].shard_kind() {
        return Err(FusionError::MixedCollectiveKinds(a, b));
    }
    let shard_kind = g.nodes[a].shard_kind();

    let mut inputs = g.nodes[a].inputs.clone();
    for &i in &g.nodes[b].inputs {
        if !inputs.contains(&i) {
            inputs.push(i);
        }
    }
    let bytes = g.nodes[a].bytes_out + g.nodes[b].bytes_out;
    let elems = (bytes / g.nodes[a].dtype.bytes() as f64) as usize;
    let mut ar_constituents = g.nodes[a].ar_constituents.clone();
    ar_constituents.extend_from_slice(&g.nodes[b].ar_constituents);
    let bytes_in = external_input_bytes(g, &inputs);
    let dtype = g.nodes[a].dtype;

    let fused_id = g.push(Node {
        id: 0,
        name: format!("fused_ar({},{})", g.nodes[a].name, g.nodes[b].name),
        kind: OpKind::AllReduce,
        role: Role::Comm,
        orig_inputs: inputs.clone(),
        inputs,
        shape: crate::graph::Shape::new(&[elems]),
        dtype,
        flops: 0.0,
        bytes_in,
        bytes_out: bytes,
        fused: None,
        ar_constituents,
        // Tensor fusion resets chunking: a fused AR is a *new* collective
        // and starts whole-tensor; the search re-chunks it explicitly if
        // that wins (legality rule, DESIGN.md §13).
        chunk: None,
        // Sharding carries over: both constituents have the same kind
        // (checked above), and the fused collective keeps it — stored in
        // canonical form so an unsharded fusion stays `None`.
        shard: if shard_kind == CollectiveKind::ReduceScatterAllGather {
            Some(ShardSpec::new(shard_kind))
        } else {
            None
        },
        deleted: false,
    });

    // Redirect consumers (optimizer updates) of both AllReduces.
    let mut redirected: Vec<NodeId> = Vec::new();
    for n in 0..fused_id {
        if g.nodes[n].deleted {
            continue;
        }
        let mut hit = false;
        for idx in 0..g.nodes[n].inputs.len() {
            let i = g.nodes[n].inputs[idx];
            if i == a || i == b {
                g.nodes[n].inputs[idx] = fused_id;
                hit = true;
            }
        }
        if hit {
            redirected.push(n);
            // A consumer of both constituents now lists the fused AR twice.
            dedup_fused_ref_in_place(&mut g.nodes[n].inputs, fused_id);
        }
    }
    g.nodes[a].deleted = true;
    g.nodes[b].deleted = true;

    g.invalidate_adjacency();
    debug_assert!(g.validate().is_ok(), "AR fusion broke the graph");
    Ok(FusionEffects { fused: fused_id, redirected, pred_deleted: true })
}

/// Set the chunk count of a live AllReduce (`count == 1` un-chunks it).
/// Returns the AllReduce's id. See [`set_chunks_explain`] for legality.
pub fn set_chunks(g: &mut TrainingGraph, ar: NodeId, count: u32) -> Result<NodeId, FusionError> {
    set_chunks_explain(g, ar, count).map(|fx| fx.fused)
}

/// [`set_chunks`] returning the full [`FusionEffects`] record.
///
/// Legality rules (DESIGN.md §13):
/// * `ar` must be a live AllReduce;
/// * `1 <= count <= MAX_CHUNKS`;
/// * for `count >= 2`, every chunk must carry at least [`MIN_CHUNK_BYTES`]
///   (`bytes_out / count >= MIN_CHUNK_BYTES`);
/// * `count` must differ from the current chunk count (a no-op rewrite
///   would only produce fingerprint-duplicate children).
///
/// This is an **in-place** edit: no node is created or tombstoned and no
/// edge moves, so cached adjacency stays valid and is *not* invalidated.
/// The AR's comm cost depends only on `bytes_out`, which is unchanged, so
/// per-node cost tables built against the parent remain valid too — the
/// delta simulator's `CostTable::extend_in` contract holds.
pub fn set_chunks_explain(
    g: &mut TrainingGraph,
    ar: NodeId,
    count: u32,
) -> Result<FusionEffects, FusionError> {
    if ar >= g.nodes.len() || g.nodes[ar].deleted || g.nodes[ar].kind != OpKind::AllReduce {
        return Err(FusionError::NotAllReduce(ar));
    }
    if count == 0 || count > MAX_CHUNKS {
        return Err(FusionError::BadChunking(ar, count, "count out of range"));
    }
    if count == g.nodes[ar].chunk_count() {
        return Err(FusionError::BadChunking(ar, count, "already at this chunk count"));
    }
    if count >= 2 && g.nodes[ar].is_sharded_collective() {
        return Err(FusionError::BadChunking(ar, count, "collective is sharded (rs+ag)"));
    }
    if count >= 2 && g.nodes[ar].bytes_out / count as f64 < MIN_CHUNK_BYTES {
        return Err(FusionError::BadChunking(ar, count, "chunks would fall below MIN_CHUNK_BYTES"));
    }
    // Canonical form: count <= 1 is stored as None so fingerprints of
    // "never chunked" and "chunked then reset" graphs coincide.
    g.nodes[ar].chunk = if count >= 2 { Some(ChunkSpec::new(count)) } else { None };
    debug_assert!(g.validate().is_ok(), "chunking broke the graph");
    Ok(FusionEffects { fused: ar, redirected: Vec::new(), pred_deleted: false })
}

/// Chunk counts the vocabulary offers for `ar`: 1 (un-chunk) and powers of
/// two up to `max_chunks` (itself capped at [`MAX_CHUNKS`]), each
/// respecting [`MIN_CHUNK_BYTES`], excluding the AR's current count.
pub fn chunk_candidates(g: &TrainingGraph, ar: NodeId, max_chunks: u32) -> Vec<u32> {
    let Some(n) = g.nodes.get(ar) else { return Vec::new() };
    if n.deleted || n.kind != OpKind::AllReduce {
        return Vec::new();
    }
    let cur = n.chunk_count();
    let cap = max_chunks.min(MAX_CHUNKS);
    let mut out = Vec::new();
    let mut k = 1u32;
    while k <= cap {
        if k != cur && (k == 1 || n.bytes_out / k as f64 >= MIN_CHUNK_BYTES) {
            out.push(k);
        }
        if k > cap / 2 {
            break;
        }
        k *= 2;
    }
    out
}

/// Set the collective kind of a live AllReduce (`AllReduce` un-shards
/// it). Returns the collective's id. See [`set_sharding_explain`].
pub fn set_sharding(
    g: &mut TrainingGraph,
    ar: NodeId,
    kind: CollectiveKind,
) -> Result<NodeId, FusionError> {
    set_sharding_explain(g, ar, kind).map(|fx| fx.fused)
}

/// [`set_sharding`] returning the full [`FusionEffects`] record.
///
/// Legality rules (DESIGN.md §16):
/// * `ar` must be a live AllReduce;
/// * the graph must span at least two workers (a single replica has no
///   shards to scatter over);
/// * every consumer of the collective must be an optimizer update — the
///   split schedule moves the parameter re-replication (all-gather)
///   after/around the optimizer step, which is only sound when nothing
///   else reads the fully-reduced gradient;
/// * `kind` must differ from the current collective kind (a no-op
///   rewrite would only produce fingerprint-duplicate children).
///
/// Activating sharding resets chunking (a sharded collective is never
/// chunked — the phase split already pipelines it); un-sharding leaves
/// the collective whole-tensor.
///
/// This is an **in-place** edit like [`set_chunks`]: no node is created
/// or tombstoned and no edge moves, so cached adjacency stays valid and
/// is *not* invalidated. The per-node cost-table entry for the
/// collective keeps holding the *unsharded* full-all-reduce time —
/// the simulator derives the reduce-scatter/all-gather phase costs from
/// it inside the event loop — so tables built against the parent remain
/// valid and `CostTable::extend_in`'s contract holds.
pub fn set_sharding_explain(
    g: &mut TrainingGraph,
    ar: NodeId,
    kind: CollectiveKind,
) -> Result<FusionEffects, FusionError> {
    if ar >= g.nodes.len() || g.nodes[ar].deleted || g.nodes[ar].kind != OpKind::AllReduce {
        return Err(FusionError::NotAllReduce(ar));
    }
    if kind == g.nodes[ar].shard_kind() {
        return Err(FusionError::BadSharding(ar, "already at this collective kind"));
    }
    if kind == CollectiveKind::ReduceScatterAllGather {
        if g.num_workers < 2 {
            return Err(FusionError::BadSharding(ar, "needs >= 2 workers to shard over"));
        }
        let all_opt = g
            .live()
            .filter(|n| n.inputs.contains(&ar))
            .all(|n| n.role == Role::Optimizer);
        if !all_opt {
            return Err(FusionError::BadSharding(
                ar,
                "a non-optimizer consumer reads the reduced gradient",
            ));
        }
        g.nodes[ar].chunk = None;
        g.nodes[ar].shard = Some(ShardSpec::new(kind));
    } else {
        // Canonical form: a DDP all-reduce is stored as None so
        // fingerprints of "never sharded" and "sharded then reset"
        // graphs coincide.
        g.nodes[ar].shard = None;
    }
    debug_assert!(g.validate().is_ok(), "sharding broke the graph");
    Ok(FusionEffects { fused: ar, redirected: Vec::new(), pred_deleted: false })
}

/// Collective kinds the vocabulary offers for `ar`: the one kind it is
/// not currently using, when switching to it would be legal (empty for
/// non-collectives or when sharding's preconditions fail).
pub fn shard_candidates(g: &TrainingGraph, ar: NodeId) -> Vec<CollectiveKind> {
    let Some(n) = g.nodes.get(ar) else { return Vec::new() };
    if n.deleted || n.kind != OpKind::AllReduce {
        return Vec::new();
    }
    match n.shard_kind() {
        CollectiveKind::ReduceScatterAllGather => vec![CollectiveKind::AllReduce],
        CollectiveKind::AllReduce => {
            let legal = g.num_workers >= 2
                && g.live()
                    .filter(|c| c.inputs.contains(&ar))
                    .all(|c| c.role == Role::Optimizer);
            if legal {
                vec![CollectiveKind::ReduceScatterAllGather]
            } else {
                Vec::new()
            }
        }
    }
}

/// Candidate (pred, succ) op-fusion pairs in the current graph.
pub fn op_fusion_candidates(g: &TrainingGraph) -> Vec<(NodeId, NodeId)> {
    let mut out = Vec::new();
    for n in g.live() {
        if !(n.kind.is_fusible_compute() || n.kind == OpKind::Fused) {
            continue;
        }
        for &p in &n.inputs {
            if is_live_compute(g, p) {
                out.push((p, n.id));
            }
        }
    }
    out
}

/// One applied rewrite, recorded with the exact operands that succeeded so
/// it can be replayed deterministically on a copy of the same parent graph.
/// This is the search's candidate *delta* encoding: a queued candidate is
/// (parent index, `Vec<Mutation>`) instead of a full graph clone.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mutation {
    FuseOps { pred: NodeId, succ: NodeId, kind: FusionKind },
    FuseAllReduce { a: NodeId, b: NodeId },
    SetChunks { ar: NodeId, count: u32 },
    SetSharding { ar: NodeId, kind: CollectiveKind },
}

impl Mutation {
    /// Re-apply this rewrite. On the graph state it was recorded against
    /// this cannot fail; an error means the caller replayed out of order.
    pub fn replay(&self, g: &mut TrainingGraph) -> Result<NodeId, FusionError> {
        match *self {
            Mutation::FuseOps { pred, succ, kind } => fuse_ops(g, pred, succ, kind),
            Mutation::FuseAllReduce { a, b } => fuse_allreduce(g, a, b),
            Mutation::SetChunks { ar, count } => set_chunks(g, ar, count),
            Mutation::SetSharding { ar, kind } => set_sharding(g, ar, kind),
        }
    }
}

/// The live rewrite-candidate pool of a graph — op-fusion (pred, succ)
/// pairs plus live AllReduce ids — maintained *incrementally* across
/// mutations instead of being re-enumerated from the graph after every
/// application (the pre-refactor hot-path cost). Pair updates are O(pool)
/// retains with zero allocation; correctness against a from-scratch
/// rebuild is property-tested (`incremental_matches_rebuild`).
#[derive(Debug, Clone, Default)]
pub struct CandidateSet {
    pairs: Vec<(NodeId, NodeId)>,
    ars: Vec<NodeId>,
}

impl CandidateSet {
    /// Enumerate from scratch.
    pub fn build(g: &TrainingGraph) -> CandidateSet {
        CandidateSet { pairs: op_fusion_candidates(g), ars: g.allreduces() }
    }

    /// Current op-fusion pairs. Order is deterministic but differs from
    /// [`op_fusion_candidates`] once incremental updates have happened.
    pub fn op_pairs(&self) -> &[(NodeId, NodeId)] {
        &self.pairs
    }

    /// Current live AllReduce ids (ascending — fused ARs get the largest
    /// arena id, so incremental maintenance preserves the sort).
    pub fn allreduces(&self) -> &[NodeId] {
        &self.ars
    }

    /// Apply an op fusion through the set, patching the pair pool from the
    /// rewrite's [`FusionEffects`] (returned for the caller's own
    /// incremental state — the search's delta-sim mutation frontier).
    pub fn apply_op_fusion(
        &mut self,
        g: &mut TrainingGraph,
        pred: NodeId,
        succ: NodeId,
        kind: FusionKind,
    ) -> Result<FusionEffects, FusionError> {
        let fx = fuse_ops_explain(g, pred, succ, kind)?;
        // `succ` is always tombstoned; `pred` only when the rewrite says so
        // (duplicate fusion keeps the replica live, and its other pairs
        // with it).
        self.pairs.retain(|&(p, s)| {
            p != succ && s != succ && (!fx.pred_deleted || (p != pred && s != pred))
        });
        let f = fx.fused;
        for &i in &g.nodes[f].inputs {
            if is_live_compute(g, i) {
                self.pairs.push((i, f));
            }
        }
        for &c in &fx.redirected {
            let k = g.nodes[c].kind;
            if k.is_fusible_compute() || k == OpKind::Fused {
                self.pairs.push((f, c));
            }
        }
        Ok(fx)
    }

    /// Apply an AllReduce fusion through the set, patching the AR pool.
    pub fn apply_ar_fusion(
        &mut self,
        g: &mut TrainingGraph,
        a: NodeId,
        b: NodeId,
    ) -> Result<FusionEffects, FusionError> {
        let fx = fuse_allreduce_explain(g, a, b)?;
        self.ars.retain(|&x| x != a && x != b);
        self.ars.push(fx.fused);
        Ok(fx)
    }

    /// Apply a chunking rewrite through the set. In-place: neither pool
    /// changes (no node is created or tombstoned).
    pub fn apply_chunking(
        &mut self,
        g: &mut TrainingGraph,
        ar: NodeId,
        count: u32,
    ) -> Result<FusionEffects, FusionError> {
        set_chunks_explain(g, ar, count)
    }

    /// Apply a sharding rewrite through the set. In-place: neither pool
    /// changes (no node is created or tombstoned).
    pub fn apply_sharding(
        &mut self,
        g: &mut TrainingGraph,
        ar: NodeId,
        kind: CollectiveKind,
    ) -> Result<FusionEffects, FusionError> {
        set_sharding_explain(g, ar, kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::{OpKind, Role};

    /// x -> m1 -> m2 -> sig ; m1 also feeds an AllReduce (gradient-ish).
    fn diamond() -> (TrainingGraph, NodeId, NodeId, NodeId, NodeId) {
        let mut b = GraphBuilder::new("d", 4);
        let x = b.constant("x", &[1024]);
        let m1 = b.compute(OpKind::Mul, "m1", &[x], &[1024], Role::Backward);
        let m2 = b.compute(OpKind::Mul, "m2", &[m1], &[1024], Role::Backward);
        let sg = b.compute(OpKind::Sigmoid, "sig", &[m2], &[1024], Role::Backward);
        let ar = b.allreduce("ar", m1, &[1024]);
        let g = b.finish();
        let _ = sg;
        (g, x, m1, m2, ar)
    }

    #[test]
    fn nondup_fusion_redirects_allreduce() {
        let (mut g, _x, m1, m2, ar) = diamond();
        let f = fuse_ops(&mut g, m1, m2, FusionKind::NonDuplicate).unwrap();
        assert!(g.nodes[m1].deleted && g.nodes[m2].deleted);
        // AllReduce now waits on the fused op — delayed communication.
        assert_eq!(g.nodes[ar].inputs, vec![f]);
        assert!(g.validate().is_ok());
        // Group contains both members, none duplicated.
        let grp = g.nodes[f].fused.as_ref().unwrap();
        assert_eq!(grp.ops.len(), 2);
        assert!(grp.ops.iter().all(|o| !o.duplicated));
        assert_eq!(grp.edges, vec![(0, 1)]);
        // Fused kernel must still materialize m1's output for the AR.
        assert_eq!(g.nodes[f].bytes_out, 2.0 * 1024.0 * 4.0);
    }

    #[test]
    fn dup_fusion_keeps_pred_live() {
        let (mut g, _x, m1, m2, ar) = diamond();
        let f = fuse_ops(&mut g, m1, m2, FusionKind::Duplicate).unwrap();
        assert!(!g.nodes[m1].deleted, "replica stays live");
        assert!(g.nodes[m2].deleted);
        // AllReduce still fed by the live replica — early availability.
        assert_eq!(g.nodes[ar].inputs, vec![m1]);
        let grp = g.nodes[f].fused.as_ref().unwrap();
        assert_eq!(grp.ops.iter().filter(|o| o.duplicated).count(), 1);
        // Only the successor's output is materialized.
        assert_eq!(g.nodes[f].bytes_out, 1024.0 * 4.0);
        // Extra compute is paid.
        assert_eq!(g.nodes[f].flops, g.nodes[m1].flops + 1024.0);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn dup_fusion_dead_pred_tombstoned() {
        // Chain a -> b with no other consumers: duplicate fusion leaves no
        // reason to keep `a`.
        let mut b = GraphBuilder::new("c", 2);
        let x = b.constant("x", &[16]);
        let a1 = b.compute(OpKind::Add, "a1", &[x], &[16], Role::Forward);
        let a2 = b.compute(OpKind::Add, "a2", &[a1], &[16], Role::Forward);
        let mut g = b.finish();
        fuse_ops(&mut g, a1, a2, FusionKind::Duplicate).unwrap();
        assert!(g.nodes[a1].deleted);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn cycle_rejected_for_nondup() {
        // p -> t -> s and p -> s: non-duplicate fusion of (p, s) would cycle.
        let mut b = GraphBuilder::new("y", 2);
        let x = b.constant("x", &[16]);
        let p = b.compute(OpKind::Add, "p", &[x], &[16], Role::Forward);
        let t = b.compute(OpKind::Mul, "t", &[p], &[16], Role::Forward);
        let s = b.compute(OpKind::Add, "s", &[p, t], &[16], Role::Forward);
        let mut g = b.finish();
        assert_eq!(
            fuse_ops(&mut g, p, s, FusionKind::NonDuplicate),
            Err(FusionError::WouldCycle(p, s))
        );
        // Duplicate fusion is fine.
        let f = fuse_ops(&mut g, p, s, FusionKind::Duplicate).unwrap();
        assert!(g.validate().is_ok());
        assert_eq!(g.nodes[f].inputs, vec![x, t]);
    }

    #[test]
    fn recursive_fusion_grows_group() {
        let (mut g, x, m1, m2, _ar) = diamond();
        let f1 = fuse_ops(&mut g, m1, m2, FusionKind::NonDuplicate).unwrap();
        // Fuse the sigmoid in too: f1 -> sig.
        let sig = g
            .live()
            .find(|n| n.kind == OpKind::Sigmoid)
            .map(|n| n.id)
            .unwrap();
        let f2 = fuse_ops(&mut g, f1, sig, FusionKind::NonDuplicate).unwrap();
        let grp = g.nodes[f2].fused.as_ref().unwrap();
        assert_eq!(grp.ops.len(), 3);
        assert_eq!(grp.edges.len(), 2); // m1->m2, m2->sig
        assert_eq!(g.nodes[f2].inputs, vec![x]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn invalid_targets_rejected() {
        let (mut g, x, m1, _m2, ar) = diamond();
        assert!(matches!(
            fuse_ops(&mut g, x, m1, FusionKind::NonDuplicate),
            Err(FusionError::NotCompute(_))
        ));
        assert!(matches!(
            fuse_ops(&mut g, m1, ar, FusionKind::NonDuplicate),
            Err(FusionError::NotCompute(_))
        ));
        assert!(matches!(
            fuse_ops(&mut g, m1, m1, FusionKind::NonDuplicate),
            Err(FusionError::SelfFusion)
        ));
    }

    fn two_grad_graph() -> (TrainingGraph, NodeId, NodeId) {
        let mut b = GraphBuilder::new("g2", 8);
        let x = b.constant("x", &[256]);
        let g1 = b.compute(OpKind::Mul, "g1", &[x], &[256], Role::Backward);
        let g2 = b.compute(OpKind::Mul, "g2", &[g1], &[128], Role::Backward);
        let ar1 = b.allreduce("ar1", g1, &[256]);
        let ar2 = b.allreduce("ar2", g2, &[128]);
        (b.finish(), ar1, ar2)
    }

    #[test]
    fn ar_fusion_combines_bytes_and_consumers() {
        let (mut g, ar1, ar2) = two_grad_graph();
        let total = g.total_gradient_bytes();
        assert!(are_ar_neighbors(&g, ar1, ar2));
        let f = fuse_allreduce(&mut g, ar1, ar2).unwrap();
        assert!(g.nodes[ar1].deleted && g.nodes[ar2].deleted);
        assert_eq!(g.nodes[f].bytes_out, (256 + 128) as f64 * 4.0);
        assert_eq!(g.total_gradient_bytes(), total, "gradient bytes conserved");
        assert_eq!(g.nodes[f].ar_constituents, vec![ar1, ar2]);
        // Fused AR waits on both producers.
        assert_eq!(g.nodes[f].inputs.len(), 2);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn ar_fusion_requires_neighbors() {
        // Chain g1 -> .. -> g5. The neighbour relation reaches producers
        // up to two hops apart (weight-gradient ops sit one hop off the
        // backward chain, see `are_ar_neighbors`), so ar(g1)/ar(g3) ARE
        // neighbours, while ar(g1)/ar(g5) are NOT.
        let mut b = GraphBuilder::new("g5", 4);
        let x = b.constant("x", &[64]);
        let g1 = b.compute(OpKind::Mul, "g1", &[x], &[64], Role::Backward);
        let g2 = b.compute(OpKind::Mul, "g2", &[g1], &[64], Role::Backward);
        let g3 = b.compute(OpKind::Mul, "g3", &[g2], &[64], Role::Backward);
        let g4 = b.compute(OpKind::Mul, "g4", &[g3], &[64], Role::Backward);
        let g5 = b.compute(OpKind::Mul, "g5", &[g4], &[64], Role::Backward);
        let ar1 = b.allreduce("ar1", g1, &[64]);
        let ar3 = b.allreduce("ar3", g3, &[64]);
        let ar5 = b.allreduce("ar5", g5, &[64]);
        let mut g = b.finish();
        let _ = (g2, g4);
        assert!(are_ar_neighbors(&g, ar1, ar3));
        assert!(!are_ar_neighbors(&g, ar1, ar5));
        assert_eq!(fuse_allreduce(&mut g, ar1, ar5), Err(FusionError::NotNeighbors(ar1, ar5)));
        // Sibling gradients (same producer parent) are neighbours.
        let mut b2 = GraphBuilder::new("sib", 4);
        let x2 = b2.constant("x", &[64]);
        let ck = b2.compute(OpKind::Mul, "ck", &[x2], &[64], Role::Backward);
        let gw1 = b2.compute(OpKind::MatMul, "gw1", &[ck], &[64], Role::Backward);
        let gw2 = b2.compute(OpKind::MatMul, "gw2", &[ck], &[64], Role::Backward);
        let a1 = b2.allreduce("a1", gw1, &[64]);
        let a2 = b2.allreduce("a2", gw2, &[64]);
        let g2g = b2.finish();
        assert!(are_ar_neighbors(&g2g, a1, a2), "siblings must be neighbours");
    }

    #[test]
    fn ar_neighbors_after_op_fusion() {
        // Op fusion can merge the two producers into one fused op, making
        // previously non-neighbour ARs share a producer.
        let mut b = GraphBuilder::new("g4", 4);
        let x = b.constant("x", &[64]);
        let g1 = b.compute(OpKind::Mul, "g1", &[x], &[64], Role::Backward);
        let g2 = b.compute(OpKind::Mul, "g2", &[g1], &[64], Role::Backward);
        let ar1 = b.allreduce("ar1", g1, &[64]);
        let ar2 = b.allreduce("ar2", g2, &[64]);
        let mut g = b.finish();
        fuse_ops(&mut g, g1, g2, FusionKind::NonDuplicate).unwrap();
        assert!(are_ar_neighbors(&g, ar1, ar2), "same fused producer");
        fuse_allreduce(&mut g, ar1, ar2).unwrap();
        assert!(g.validate().is_ok());
    }

    #[test]
    fn candidates_enumerated() {
        let (g, _x, m1, m2, _ar) = diamond();
        let cands = op_fusion_candidates(&g);
        assert!(cands.contains(&(m1, m2)));
        // The constant is not a fusible pred.
        assert!(cands.iter().all(|&(p, _)| p != 0));
    }

    #[test]
    fn ar_fusion_effects_record_redirects() {
        let mut b = GraphBuilder::new("fx", 4);
        let x = b.constant("x", &[256]);
        let g1 = b.compute(OpKind::Mul, "g1", &[x], &[256], Role::Backward);
        let g2 = b.compute(OpKind::Mul, "g2", &[g1], &[128], Role::Backward);
        let p1 = b.param("w1", &[256]);
        let p2 = b.param("w2", &[128]);
        let ar1 = b.allreduce("ar1", g1, &[256]);
        let ar2 = b.allreduce("ar2", g2, &[128]);
        let u1 = b.optimizer_update("u1", &[ar1, p1]);
        let u2 = b.optimizer_update("u2", &[ar2, p2]);
        let mut g = b.finish();
        let fx = fuse_allreduce_explain(&mut g, ar1, ar2).unwrap();
        assert!(fx.pred_deleted);
        assert_eq!(fx.redirected, vec![u1, u2]);
        assert_eq!(g.nodes[u1].inputs, vec![fx.fused, p1]);
        // Frontier covers the fused AR, the rewired optimizer updates and
        // the gradient producers whose consumer sets changed.
        let mut frontier = vec![ar1, ar2];
        fx.extend_frontier(&g, &mut frontier);
        for id in [ar1, ar2, fx.fused, u1, u2, g1, g2] {
            assert!(frontier.contains(&id), "frontier missing {id}");
        }
    }

    #[test]
    fn op_fusion_frontier_covers_touched_nodes() {
        let (mut g, x, m1, m2, ar) = diamond();
        let fx = fuse_ops_explain(&mut g, m1, m2, FusionKind::NonDuplicate).unwrap();
        let mut frontier = vec![m1, m2];
        fx.extend_frontier(&g, &mut frontier);
        // x feeds the fused kernel now; ar and sig were redirected.
        let sig = g.live().find(|n| n.kind == OpKind::Sigmoid).map(|n| n.id).unwrap();
        for id in [m1, m2, fx.fused, x, ar, sig] {
            assert!(frontier.contains(&id), "frontier missing {id}");
        }
    }

    #[test]
    fn redirect_preserves_unrelated_duplicate_operands() {
        // sq consumes m twice (x·x style) AND the fusion predecessor:
        // redirection must rewrite only the p1 reference, leaving the
        // legitimate duplicate m-edges intact.
        let mut b = GraphBuilder::new("rd", 2);
        let x = b.constant("x", &[16]);
        let m = b.compute(OpKind::Mul, "m", &[x], &[16], Role::Forward);
        let p1 = b.compute(OpKind::Add, "p1", &[x], &[16], Role::Forward);
        let p2 = b.compute(OpKind::Add, "p2", &[p1], &[16], Role::Forward);
        let sq = b.compute(OpKind::Mul, "sq", &[m, m, p1], &[16], Role::Forward);
        let mut g = b.finish();
        let fx = fuse_ops_explain(&mut g, p1, p2, FusionKind::NonDuplicate).unwrap();
        assert_eq!(g.nodes[sq].inputs, vec![m, m, fx.fused]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn consumer_of_both_operands_gets_single_fused_ref() {
        // c consumes pred AND succ: after redirection both references
        // point at the fused node and must collapse to one.
        let mut b = GraphBuilder::new("cb", 2);
        let x = b.constant("x", &[16]);
        let p = b.compute(OpKind::Add, "p", &[x], &[16], Role::Forward);
        let s = b.compute(OpKind::Mul, "s", &[p], &[16], Role::Forward);
        let c = b.compute(OpKind::Add, "c", &[p, s], &[16], Role::Forward);
        let mut g = b.finish();
        let fx = fuse_ops_explain(&mut g, p, s, FusionKind::NonDuplicate).unwrap();
        assert_eq!(g.nodes[c].inputs, vec![fx.fused]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn chunking_legality_enforced() {
        // ar1 carries 256 f32 elems = 1024 bytes: 2 chunks of 512 bytes
        // fall below MIN_CHUNK_BYTES and must be rejected.
        let (mut g, ar1, _ar2) = two_grad_graph();
        assert!(matches!(
            set_chunks(&mut g, ar1, 2),
            Err(FusionError::BadChunking(_, 2, _))
        ));
        // Non-AR target, zero count, over-cap count, and no-op count.
        assert_eq!(set_chunks(&mut g, 0, 2), Err(FusionError::NotAllReduce(0)));
        assert!(matches!(set_chunks(&mut g, ar1, 0), Err(FusionError::BadChunking(_, 0, _))));
        assert!(matches!(
            set_chunks(&mut g, ar1, MAX_CHUNKS + 1),
            Err(FusionError::BadChunking(_, _, _))
        ));
        assert!(matches!(set_chunks(&mut g, ar1, 1), Err(FusionError::BadChunking(_, 1, _))));
        assert_eq!(g.nodes[ar1].chunk_count(), 1, "rejected rewrites must not edit");

        // A big enough tensor chunks fine, and count=1 resets to canonical
        // None (fingerprint equal to the never-chunked graph).
        let mut b = GraphBuilder::new("big", 4);
        let x = b.constant("x", &[1 << 16]);
        let gr = b.compute(OpKind::Mul, "g", &[x], &[1 << 16], Role::Backward);
        let ar = b.allreduce("ar", gr, &[1 << 16]);
        let mut g = b.finish();
        let fp0 = g.fingerprint();
        let fx = set_chunks_explain(&mut g, ar, 8).unwrap();
        assert_eq!(fx.fused, ar);
        assert!(fx.redirected.is_empty() && !fx.pred_deleted);
        assert_eq!(g.nodes[ar].chunk_count(), 8);
        assert!(g.has_chunking());
        assert_ne!(g.fingerprint(), fp0);
        set_chunks(&mut g, ar, 1).unwrap();
        assert!(g.nodes[ar].chunk.is_none(), "count=1 stored canonically as None");
        assert_eq!(g.fingerprint(), fp0);
    }

    #[test]
    fn chunk_candidates_respect_floor_and_current() {
        let mut b = GraphBuilder::new("cc", 4);
        let x = b.constant("x", &[2048]);
        let gr = b.compute(OpKind::Mul, "g", &[x], &[2048], Role::Backward);
        let ar = b.allreduce("ar", gr, &[2048]); // 8192 bytes
        let mut g = b.finish();
        // 8192 / 8 = 1024 is the floor; 16 would be 512.
        assert_eq!(chunk_candidates(&g, ar, 32), vec![2, 4, 8]);
        set_chunks(&mut g, ar, 4).unwrap();
        let cands = chunk_candidates(&g, ar, 32);
        assert!(cands.contains(&1) && !cands.contains(&4), "current count excluded, 1 offered");
        // Every offered count is legal by construction.
        for &k in &cands {
            let mut h = g.clone();
            set_chunks(&mut h, ar, k).unwrap();
        }
        // Non-AR and dead targets yield nothing.
        assert!(chunk_candidates(&g, x, 32).is_empty());
    }

    #[test]
    fn ar_fusion_resets_chunking() {
        let mut b = GraphBuilder::new("rst", 4);
        let x = b.constant("x", &[4096]);
        let g1 = b.compute(OpKind::Mul, "g1", &[x], &[4096], Role::Backward);
        let g2 = b.compute(OpKind::Mul, "g2", &[g1], &[4096], Role::Backward);
        let ar1 = b.allreduce("ar1", g1, &[4096]);
        let ar2 = b.allreduce("ar2", g2, &[4096]);
        let mut g = b.finish();
        set_chunks(&mut g, ar1, 4).unwrap();
        let f = fuse_allreduce(&mut g, ar1, ar2).unwrap();
        assert_eq!(g.nodes[f].chunk_count(), 1, "fused AR starts whole-tensor");
        assert!(!g.has_chunking());
    }

    /// Two gradients, two ARs, each feeding an optimizer update.
    fn sharded_ready_graph() -> (TrainingGraph, NodeId, NodeId, NodeId, NodeId) {
        let mut b = GraphBuilder::new("sh", 4);
        let x = b.constant("x", &[256]);
        let g1 = b.compute(OpKind::Mul, "g1", &[x], &[256], Role::Backward);
        let g2 = b.compute(OpKind::Mul, "g2", &[g1], &[128], Role::Backward);
        let p1 = b.param("w1", &[256]);
        let p2 = b.param("w2", &[128]);
        let ar1 = b.allreduce("ar1", g1, &[256]);
        let ar2 = b.allreduce("ar2", g2, &[128]);
        let u1 = b.optimizer_update("u1", &[ar1, p1]);
        let u2 = b.optimizer_update("u2", &[ar2, p2]);
        (b.finish(), ar1, ar2, u1, u2)
    }

    #[test]
    fn sharding_legality_enforced() {
        let (mut g, ar1, _ar2, _u1, _u2) = sharded_ready_graph();
        let rs = CollectiveKind::ReduceScatterAllGather;
        // Non-AR target and no-op kind.
        assert_eq!(set_sharding(&mut g, 0, rs), Err(FusionError::NotAllReduce(0)));
        assert!(matches!(
            set_sharding(&mut g, ar1, CollectiveKind::AllReduce),
            Err(FusionError::BadSharding(_, _))
        ));
        // Legal activation: chunking resets, fingerprint moves.
        let fp0 = g.fingerprint();
        let fx = set_sharding_explain(&mut g, ar1, rs).unwrap();
        assert_eq!(fx.fused, ar1);
        assert!(fx.redirected.is_empty() && !fx.pred_deleted);
        assert!(g.nodes[ar1].is_sharded_collective());
        assert!(g.has_sharding());
        assert_ne!(g.fingerprint(), fp0);
        // A sharded collective may not be chunked.
        assert!(matches!(
            set_chunks(&mut g, ar1, 2),
            Err(FusionError::BadChunking(_, 2, _))
        ));
        // Un-sharding resets to canonical None — fingerprint returns.
        set_sharding(&mut g, ar1, CollectiveKind::AllReduce).unwrap();
        assert!(g.nodes[ar1].shard.is_none(), "unsharded stored canonically as None");
        assert_eq!(g.fingerprint(), fp0);
        // Single-worker graphs cannot shard.
        let mut b1 = GraphBuilder::new("w1", 1);
        let x = b1.constant("x", &[64]);
        let gr = b1.compute(OpKind::Mul, "g", &[x], &[64], Role::Backward);
        let ar = b1.allreduce("ar", gr, &[64]);
        let mut g1w = b1.finish();
        assert!(matches!(
            set_sharding(&mut g1w, ar, rs),
            Err(FusionError::BadSharding(_, _))
        ));
        // A non-optimizer consumer of the reduced gradient blocks sharding.
        let mut b2 = GraphBuilder::new("nc", 4);
        let x2 = b2.constant("x", &[64]);
        let gr2 = b2.compute(OpKind::Mul, "g", &[x2], &[64], Role::Backward);
        let ar2 = b2.allreduce("ar", gr2, &[64]);
        let _reader = b2.compute(OpKind::Mul, "norm", &[ar2], &[64], Role::Backward);
        let mut g2 = b2.finish();
        assert!(matches!(
            set_sharding(&mut g2, ar2, rs),
            Err(FusionError::BadSharding(_, _))
        ));
        assert!(shard_candidates(&g2, ar2).is_empty());
    }

    #[test]
    fn shard_candidates_offer_the_other_kind() {
        let (mut g, ar1, _ar2, _u1, _u2) = sharded_ready_graph();
        let rs = CollectiveKind::ReduceScatterAllGather;
        assert_eq!(shard_candidates(&g, ar1), vec![rs]);
        set_sharding(&mut g, ar1, rs).unwrap();
        assert_eq!(shard_candidates(&g, ar1), vec![CollectiveKind::AllReduce]);
        // Non-AR targets yield nothing.
        assert!(shard_candidates(&g, 0).is_empty());
    }

    #[test]
    fn ar_fusion_requires_same_collective_kind() {
        let (mut g, ar1, ar2, _u1, _u2) = sharded_ready_graph();
        let rs = CollectiveKind::ReduceScatterAllGather;
        set_sharding(&mut g, ar1, rs).unwrap();
        assert_eq!(
            fuse_allreduce(&mut g, ar1, ar2),
            Err(FusionError::MixedCollectiveKinds(ar1, ar2))
        );
        // Shard both the same way and fusion works, carrying the kind.
        set_sharding(&mut g, ar2, rs).unwrap();
        let f = fuse_allreduce(&mut g, ar1, ar2).unwrap();
        assert!(g.nodes[f].is_sharded_collective(), "fusion carries the shared kind");
        assert_eq!(g.nodes[f].chunk_count(), 1);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn sharding_resets_chunking() {
        let mut b = GraphBuilder::new("shck", 4);
        let x = b.constant("x", &[1 << 16]);
        let gr = b.compute(OpKind::Mul, "g", &[x], &[1 << 16], Role::Backward);
        let p = b.param("w", &[1 << 16]);
        let ar = b.allreduce("ar", gr, &[1 << 16]);
        b.optimizer_update("u", &[ar, p]);
        let mut g = b.finish();
        set_chunks(&mut g, ar, 8).unwrap();
        assert!(g.has_chunking());
        set_sharding(&mut g, ar, CollectiveKind::ReduceScatterAllGather).unwrap();
        assert!(!g.has_chunking(), "sharding resets the chunk spec");
        assert!(g.nodes[ar].chunk.is_none());
        assert!(g.has_sharding());
    }

    #[test]
    fn shard_mutation_replay_reproduces_rewrite() {
        let (mut g, ar1, _ar2, _u1, _u2) = sharded_ready_graph();
        let mut h = g.clone();
        let rs = CollectiveKind::ReduceScatterAllGather;
        set_sharding(&mut g, ar1, rs).unwrap();
        Mutation::SetSharding { ar: ar1, kind: rs }.replay(&mut h).unwrap();
        assert_eq!(g.fingerprint(), h.fingerprint());
        assert_eq!(g, h);
    }

    #[test]
    fn chunk_mutation_replay_reproduces_rewrite() {
        let mut b = GraphBuilder::new("rp", 4);
        let x = b.constant("x", &[1 << 14]);
        let gr = b.compute(OpKind::Mul, "g", &[x], &[1 << 14], Role::Backward);
        let ar = b.allreduce("ar", gr, &[1 << 14]);
        let mut g = b.finish();
        let mut h = g.clone();
        set_chunks(&mut g, ar, 8).unwrap();
        Mutation::SetChunks { ar, count: 8 }.replay(&mut h).unwrap();
        assert_eq!(g.fingerprint(), h.fingerprint());
        assert_eq!(g, h);
    }

    #[test]
    fn mutation_replay_reproduces_rewrite() {
        let (mut g, _x, m1, m2, _ar) = diamond();
        let mut h = g.clone();
        fuse_ops(&mut g, m1, m2, FusionKind::NonDuplicate).unwrap();
        Mutation::FuseOps { pred: m1, succ: m2, kind: FusionKind::NonDuplicate }
            .replay(&mut h)
            .unwrap();
        assert_eq!(g.fingerprint(), h.fingerprint());
        assert_eq!(g, h);
    }

    /// Incremental candidate maintenance must stay set-equal to a
    /// from-scratch enumeration across random mutation sequences.
    #[test]
    fn incremental_matches_rebuild() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x5E7);
        for case in 0..40 {
            // Random-ish layered graph with sibling gradients + ARs.
            let mut b = crate::graph::builder::GraphBuilder::new("cs", 4);
            let mut prev = b.constant("x", &[128]);
            let layers = 2 + (case % 4);
            for l in 0..layers {
                let m = b.compute(OpKind::Mul, &format!("m{l}"), &[prev], &[128], Role::Backward);
                let t = b.compute(OpKind::Tanh, &format!("t{l}"), &[m], &[128], Role::Backward);
                let gw =
                    b.compute(OpKind::MatMul, &format!("gw{l}"), &[m], &[64], Role::Backward);
                let p = b.param(&format!("w{l}"), &[64]);
                let ar = b.allreduce(&format!("ar{l}"), gw, &[64]);
                b.optimizer_update(&format!("u{l}"), &[ar, p]);
                prev = t;
            }
            let mut g = b.finish();
            let mut cset = CandidateSet::build(&g);
            for _ in 0..10 {
                if rng.gen_bool(0.7) {
                    let Some(&(p, s)) = rng.choose(cset.op_pairs()) else { continue };
                    let kind = if rng.gen_bool(0.5) {
                        FusionKind::NonDuplicate
                    } else {
                        FusionKind::Duplicate
                    };
                    let _ = cset.apply_op_fusion(&mut g, p, s, kind);
                } else {
                    let Some(&a) = rng.choose(cset.allreduces()) else { continue };
                    let nbrs = ar_neighbors(&g, a);
                    let Some(&bb) = rng.choose(&nbrs) else { continue };
                    let _ = cset.apply_ar_fusion(&mut g, a, bb);
                }
                let mut inc: Vec<(NodeId, NodeId)> = cset.op_pairs().to_vec();
                let mut scratch = op_fusion_candidates(&g);
                inc.sort_unstable();
                scratch.sort_unstable();
                assert_eq!(inc, scratch, "op pairs diverged (case {case})");
                let mut ars = cset.allreduces().to_vec();
                ars.sort_unstable();
                assert_eq!(ars, g.allreduces(), "AR pool diverged (case {case})");
            }
        }
    }
}
