//! Real ring-AllReduce over in-process workers — the substrate the
//! enactment phase uses to actually average gradients in the end-to-end
//! training example (DESIGN.md §2: numerics are real even though timing
//! is modelled).
//!
//! Implements the classic two-phase ring algorithm (Patarasuk & Yuan):
//! reduce-scatter (N−1 steps, each worker accumulates one chunk) followed
//! by all-gather (N−1 steps). Workers are threads exchanging chunk
//! messages over `std::sync::mpsc` channels arranged in a ring.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread;

/// Splits `len` elements into `n` contiguous chunks (first chunks one
/// element longer when `len % n != 0`). Returns (start, end) pairs.
pub fn chunk_ranges(len: usize, n: usize) -> Vec<(usize, usize)> {
    let base = len / n;
    let rem = len % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for i in 0..n {
        let sz = base + usize::from(i < rem);
        out.push((start, start + sz));
        start += sz;
    }
    out
}

/// One worker's handle into a ring of `n` workers: sends to `rank+1`,
/// receives from `rank-1`.
pub struct RingPeer {
    pub rank: usize,
    pub world: usize,
    tx_next: Sender<Vec<f32>>,
    rx_prev: Receiver<Vec<f32>>,
}

/// Build channel rings for `world` workers.
pub fn make_ring(world: usize) -> Vec<RingPeer> {
    assert!(world >= 1);
    let mut txs = Vec::with_capacity(world);
    let mut rxs = Vec::with_capacity(world);
    for _ in 0..world {
        let (tx, rx) = channel::<Vec<f32>>();
        txs.push(tx);
        rxs.push(rx);
    }
    // Worker r sends into channel r (read by r+1).
    let mut peers = Vec::with_capacity(world);
    let mut rx_iter = rxs.into_iter();
    // rx for worker r is channel (r-1+world)%world; rebuild in order.
    let mut rx_map: Vec<Option<Receiver<Vec<f32>>>> = (0..world).map(|_| rx_iter.next()).collect();
    for rank in 0..world {
        let tx_next = txs[rank].clone();
        let rx_prev = rx_map[(rank + world - 1) % world].take().expect("rx taken twice");
        peers.push(RingPeer { rank, world, tx_next, rx_prev });
    }
    peers
}

impl RingPeer {
    /// In-place ring AllReduce (sum) of `data` across all workers. Every
    /// worker must call this with an equal-length buffer. After return,
    /// every buffer holds the elementwise sum.
    pub fn allreduce_sum(&self, data: &mut [f32]) {
        let n = self.world;
        if n == 1 {
            return;
        }
        let ranges = chunk_ranges(data.len(), n);

        // Phase 1: reduce-scatter. In step s, send chunk (rank - s) and
        // receive + accumulate chunk (rank - s - 1).
        for s in 0..n - 1 {
            let send_idx = (self.rank + n - s) % n;
            let recv_idx = (self.rank + n - s - 1) % n;
            let (a, bnd) = ranges[send_idx];
            self.tx_next
                .send(data[a..bnd].to_vec())
                .expect("ring peer hung up (reduce-scatter)");
            let incoming = self.rx_prev.recv().expect("ring recv failed (reduce-scatter)");
            let (a, bnd) = ranges[recv_idx];
            for (dst, src) in data[a..bnd].iter_mut().zip(incoming.iter()) {
                *dst += *src;
            }
        }

        // Phase 2: all-gather. In step s, send the chunk finalized last
        // step and receive the previous worker's finalized chunk.
        for s in 0..n - 1 {
            let send_idx = (self.rank + 1 + n - s) % n;
            let recv_idx = (self.rank + n - s) % n;
            let (a, bnd) = ranges[send_idx];
            self.tx_next
                .send(data[a..bnd].to_vec())
                .expect("ring peer hung up (all-gather)");
            let incoming = self.rx_prev.recv().expect("ring recv failed (all-gather)");
            let (a, bnd) = ranges[recv_idx];
            data[a..bnd].copy_from_slice(&incoming);
        }
    }

    /// AllReduce-mean: sum then divide by world size (gradient averaging).
    pub fn allreduce_mean(&self, data: &mut [f32]) {
        self.allreduce_sum(data);
        let inv = 1.0 / self.world as f32;
        for x in data.iter_mut() {
            *x *= inv;
        }
    }
}

/// Convenience: run `world` worker closures on threads, each given its
/// ring peer; returns their outputs in rank order.
pub fn run_workers<T, F>(world: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(RingPeer) -> T + Send + Sync + 'static,
{
    let peers = make_ring(world);
    let f = std::sync::Arc::new(f);
    let mut handles = Vec::new();
    for peer in peers {
        let f = f.clone();
        handles.push(thread::spawn(move || f(peer)));
    }
    handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn chunks_cover_exactly() {
        for len in [0usize, 1, 7, 64, 100] {
            for n in [1usize, 2, 3, 8] {
                let r = chunk_ranges(len, n);
                assert_eq!(r.len(), n);
                assert_eq!(r[0].0, 0);
                assert_eq!(r[n - 1].1, len);
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0);
                }
            }
        }
    }

    #[test]
    fn allreduce_sum_matches_reference() {
        for world in [1usize, 2, 3, 4, 8] {
            let len = 103; // not divisible by world
            // Build per-worker inputs deterministically.
            let inputs: Vec<Vec<f32>> = (0..world)
                .map(|r| {
                    let mut rng = Rng::new(100 + r as u64);
                    (0..len).map(|_| (rng.gen_f64() * 2.0 - 1.0) as f32).collect()
                })
                .collect();
            let mut expect = vec![0.0f32; len];
            for inp in &inputs {
                for (e, x) in expect.iter_mut().zip(inp) {
                    *e += *x;
                }
            }
            let inputs2 = inputs.clone();
            let results = run_workers(world, move |peer| {
                let mut data = inputs2[peer.rank].clone();
                peer.allreduce_sum(&mut data);
                data
            });
            for r in &results {
                for (a, b) in r.iter().zip(&expect) {
                    assert!((a - b).abs() < 1e-4, "world={world}");
                }
            }
        }
    }

    #[test]
    fn allreduce_mean_averages() {
        let world = 4;
        let results = run_workers(world, move |peer| {
            let mut data = vec![peer.rank as f32; 10];
            peer.allreduce_mean(&mut data);
            data
        });
        for r in results {
            for x in r {
                assert!((x - 1.5).abs() < 1e-6); // mean of 0,1,2,3
            }
        }
    }

    #[test]
    fn single_worker_identity() {
        let results = run_workers(1, |peer| {
            let mut d = vec![1.0f32, 2.0, 3.0];
            peer.allreduce_sum(&mut d);
            d
        });
        assert_eq!(results[0], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn repeated_allreduces_on_same_ring() {
        let world = 3;
        let results = run_workers(world, move |peer| {
            let mut out = Vec::new();
            for round in 0..5 {
                let mut d = vec![(peer.rank + round) as f32; 8];
                peer.allreduce_sum(&mut d);
                out.push(d[0]);
            }
            out
        });
        for r in results {
            assert_eq!(r, vec![3.0, 6.0, 9.0, 12.0, 15.0]); // sum of ranks+round
        }
    }
}
