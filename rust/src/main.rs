//! `disco` — CLI for the DisCo reproduction.
//!
//! ```text
//! disco search    --model transformer --cluster a [--alpha 1.05 --beta 10]
//!                 [--estimator analytical|gnn|oracle] [--chunking]
//!                 [--max-chunks 8] [--sharding] [--out strategy.json]
//!                 [--trace search.json]   # Chrome trace + convergence JSONL
//! disco serve     [--addr 127.0.0.1:7077] [--store plans.jsonl|none]
//!                 [--capacity 512] [--max-conns 256] [--no-warm]
//!                 [--no-nearest] [--cold-budget-ms 0] [--max-cold 8]
//!                 [--metrics] [--prom] [--stop]
//! disco store     fsck [--store plans.jsonl] [--repair]
//! disco plan      --model transformer [--graph module.json] [--hlo module.hlo.txt]
//!                 [--cluster a] [--addr HOST:PORT] [--store plans.jsonl]
//!                 [--unchanged 150] [--chunking] [--max-chunks 8] [--sharding]
//!                 [--expect store|warm|cold] [--out strategy.json]
//! disco enact     --strategy strategy.json --world 4 [--iterations 10]
//!                 [--quorum N] [--timeout-ms 10000] [--retries 1]
//!                 [--straggler-ms 0] [--chaos "kill@3:1,delay@2:80"]
//!                 [--expect-degraded] [--trace enact.json]
//! disco worker    --connect 127.0.0.1:7100 --rank 0 [--cluster a]
//!                 [--retry] [--max-reconnects 3] [--backoff-ms 10]
//!                 [--timeout-ms 10000]
//! disco profile   --model vgg19 --cluster a
//! disco bench     fig6|fig7|fig8|fig9|table2|fig10|table3|table4|ablation|extensions|perf|all
//!                 [--full] [--estimator ...] [--out EXPERIMENTS.md-section]
//! disco train-gnn [--per-model 800] [--epochs 30]
//! disco e2e       [--workers 4] [--steps 200]
//! disco gen-artifacts [--out artifacts]
//! disco run-hlo <case.hlo>          # conformance-corpus authoring
//! ```
//!
//! Every runtime-touching command accepts `--backend interp|pjrt`
//! (default: the in-tree HLO interpreter, which runs fully offline —
//! DESIGN.md §9).

use anyhow::{anyhow, Result};
use disco::bench::{experiments, BenchOptions, EstimatorKind, Scale};
use disco::coordinator::{enact, EnactConfig};
use disco::estimator::CostEstimator;
use disco::graph::TrainingGraph;
use disco::models::{build, ModelKind};
use disco::network::Cluster;
use disco::runtime::trainer::{train_distributed, TrainConfig};
use disco::runtime::Manifest;
use disco::search::{backtracking_search, SearchConfig};
use disco::sim::CostSource;
use disco::util::cli::Args;

fn cluster_of(args: &Args) -> Cluster {
    match args.get_or("cluster", "a") {
        "b" => Cluster::cluster_b(),
        "single" => Cluster::single_device(),
        _ => Cluster::cluster_a(),
    }
}

fn model_of(args: &Args) -> Result<ModelKind> {
    let name = args.get_or("model", "transformer");
    ModelKind::from_name(name).ok_or_else(|| {
        anyhow!(
            "unknown model '{name}' (expected one of {:?})",
            ModelKind::ALL.iter().map(|m| m.name()).collect::<Vec<_>>()
        )
    })
}

fn bench_opts(args: &Args) -> Result<BenchOptions> {
    let estimator = EstimatorKind::parse(args.get_or("estimator", "analytical"))
        .ok_or_else(|| anyhow!("estimator must be analytical|gnn|oracle"))?;
    Ok(BenchOptions {
        scale: if args.has_flag("full") { Scale::Full } else { Scale::Fast },
        estimator,
        seed: args.get_u64("seed", 0xD15C0),
        alpha: args.get_f64("alpha", 1.05),
        beta: args.get_usize("beta", 10),
    })
}

fn cmd_search(args: &Args) -> Result<()> {
    let opts = bench_opts(args)?;
    // `--config file.json` overrides cluster/device/search settings.
    let file_cfg = match args.get("config") {
        Some(path) => Some(disco::util::config::Config::from_file(path)?),
        None => None,
    };
    let cluster = file_cfg.as_ref().map(|c| c.cluster.clone()).unwrap_or_else(|| cluster_of(args));
    let kind = model_of(args)?;
    let p = disco::bench::prepare(&opts, kind, &cluster);
    let est = p.estimator(opts.estimator);
    let mut cfg: SearchConfig = match &file_cfg {
        Some(c) => c.search.clone(),
        None => opts.search_config(),
    };
    cfg.unchanged_limit = args.get_usize("unchanged", cfg.unchanged_limit);
    // `--chunking` opts the vocabulary into chunked collectives
    // (DESIGN.md §13); the config file's `search.chunking` also enables it.
    if args.has_flag("chunking") {
        cfg.methods.chunking = true;
    }
    cfg.max_chunks = args.get_usize("max-chunks", cfg.max_chunks as usize) as u32;
    // `--sharding` opts the vocabulary into reduce-scatter/all-gather
    // gradient sharding (DESIGN.md §16); `search.sharding` in the config
    // file does the same.
    if args.has_flag("sharding") {
        cfg.methods.sharding = true;
    }
    println!(
        "searching {} on cluster {} ({} devices, {} live ops, {} AllReduces; estimator={}, α={}, β={})",
        kind.name(),
        cluster.name,
        cluster.num_devices(),
        p.graph.live_count(),
        p.graph.allreduces().len(),
        est.fused.name(),
        cfg.alpha,
        cfg.beta
    );
    // `--trace out.json` records search telemetry (DESIGN.md §15):
    // Chrome-trace JSON at the given path plus a convergence-curve JSONL
    // sibling (same stem, `.jsonl`) whose last line is the final result.
    let trace_path = args.get("trace");
    let r = if let Some(path) = trace_path {
        use disco::util::trace::{to_chrome_json, to_jsonl, MemSink};
        cfg.trace = true;
        let mut sink = MemSink::default();
        let r = disco::search::backtracking_search_traced(&p.graph, &est, &cfg, &[], &mut sink);
        std::fs::write(path, to_chrome_json(&sink.events, &sink.tracks))?;
        let jsonl = std::path::Path::new(path).with_extension("jsonl");
        std::fs::write(&jsonl, to_jsonl(&sink.events))?;
        println!("wrote search trace to {path} (convergence curve: {})", jsonl.display());
        r
    } else {
        backtracking_search(&p.graph, &est, &cfg)
    };
    println!(
        "initial {:.3} ms → best {:.3} ms ({:.1}% faster); {} evals in {:.1}s",
        r.initial_cost_ms,
        r.best_cost_ms,
        (r.initial_cost_ms / r.best_cost_ms - 1.0) * 100.0,
        r.evals,
        r.elapsed.as_secs_f64()
    );
    if r.best.has_chunking() {
        let sched: Vec<String> = r
            .best
            .live()
            .filter(|n| n.chunk_count() >= 2)
            .map(|n| format!("{}×{}", n.name, n.chunk_count()))
            .collect();
        println!("chunk schedule: {}", sched.join(", "));
    }
    if r.best.has_sharding() {
        let sched: Vec<String> = r
            .best
            .live()
            .filter(|n| n.is_sharded_collective())
            .map(|n| n.name.clone())
            .collect();
        println!("sharded (reduce-scatter/all-gather): {}", sched.join(", "));
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, r.best.to_json())?;
        println!("wrote optimized strategy to {path}");
    }
    Ok(())
}

/// Service configuration from `--config` (service section) overridden by
/// direct flags.
fn serve_options(args: &Args) -> Result<disco::service::ServeOptions> {
    let svc = match args.get("config") {
        Some(path) => disco::util::config::Config::from_file(path)?.service,
        None => disco::service::ServiceConfig::default(),
    };
    let mut opts = svc.serve_options();
    if let Some(addr) = args.get("addr") {
        opts.addr = addr.to_string();
    }
    if let Some(store) = args.get("store") {
        opts.store_path = if store == "none" { None } else { Some(store.to_string()) };
    }
    opts.capacity = args.get_usize("capacity", opts.capacity);
    opts.max_conns = args.get_usize("max-conns", opts.max_conns);
    opts.cold_budget_ms = args.get_f64("cold-budget-ms", opts.cold_budget_ms).max(0.0);
    opts.max_cold = args.get_usize("max-cold", opts.max_cold);
    if args.has_flag("no-warm") {
        opts.warm.enabled = false;
    }
    if args.has_flag("no-nearest") {
        opts.warm.nearest = false;
    }
    Ok(opts)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let opts = serve_options(args)?;
    if args.has_flag("metrics") {
        let resp = disco::service::request(
            &opts.addr,
            &disco::util::json::Json::obj(vec![(
                "cmd",
                disco::util::json::Json::Str("stats".into()),
            )]),
        )?;
        if resp.get("ok").as_bool() != Some(true) {
            return Err(anyhow!("stats request failed: {}", resp.to_string()));
        }
        // BTreeMap keys iterate sorted — stable, grep-friendly output.
        if let disco::util::json::Json::Obj(fields) = &resp {
            for (k, v) in fields {
                if k != "ok" && k != "cmd" {
                    println!("{k:<24} {}", v.to_string());
                }
            }
        }
        return Ok(());
    }
    // `--prom`: one scrape of the server's Prometheus-style exposition,
    // printed raw (pipe to a file, or let CI grep it).
    if args.has_flag("prom") {
        let resp = disco::service::request(
            &opts.addr,
            &disco::util::json::Json::obj(vec![(
                "cmd",
                disco::util::json::Json::Str("metrics".into()),
            )]),
        )?;
        if resp.get("ok").as_bool() != Some(true) {
            return Err(anyhow!("metrics request failed: {}", resp.to_string()));
        }
        print!("{}", resp.get("exposition").as_str().unwrap_or(""));
        return Ok(());
    }
    if args.has_flag("stop") {
        let resp = disco::service::request(
            &opts.addr,
            &disco::util::json::Json::obj(vec![(
                "cmd",
                disco::util::json::Json::Str("shutdown".into()),
            )]),
        )?;
        if resp.get("ok").as_bool() != Some(true) {
            return Err(anyhow!("server refused shutdown: {}", resp.to_string()));
        }
        println!("disco serve at {} shutting down", opts.addr);
        return Ok(());
    }
    let server = disco::service::Server::bind(&opts)?;
    println!(
        "disco strategy service listening on {} (store: {}, capacity {}, warm-start {}, nearest {})",
        server.local_addr(),
        opts.store_path.as_deref().unwrap_or("memory-only"),
        opts.capacity,
        opts.warm.enabled,
        opts.warm.nearest,
    );
    server.run()
}

/// `disco store fsck [--store plans.jsonl] [--repair]` — offline store
/// integrity check (DESIGN.md §14). Prints the recovery report; exits 1
/// when damage is found and `--repair` was not given.
fn cmd_store(args: &Args) -> Result<()> {
    let sub = args.positional.get(1).map(|s| s.as_str()).unwrap_or("");
    if sub != "fsck" {
        return Err(anyhow!("usage: disco store fsck [--store plans.jsonl] [--repair]"));
    }
    let path = args.get_or("store", "plans.jsonl");
    let repair = args.has_flag("repair");
    let report = disco::service::fsck(std::path::Path::new(path), repair)?;
    println!("{path}: {report}");
    if !report.is_clean() && !report.repaired {
        std::process::exit(1);
    }
    Ok(())
}

/// The graph a `plan` request is about: an explicit serialized module
/// (`--graph file.json`), an HLO text module (`--hlo module.hlo.txt`),
/// or a model-zoo build.
///
/// All three sources return a plain `TrainingGraph`, so every one of
/// them flows through the same fingerprint → store-hit / warm / cold
/// resolution in `cmd_plan`. (An earlier revision special-cased
/// imports straight to a cold search, which silently bypassed the plan
/// store — imported modules never hit or warm-started.)
fn plan_graph(args: &Args, cluster: &Cluster) -> Result<TrainingGraph> {
    if let Some(path) = args.get("hlo") {
        return disco::graph::hlo_import::import_hlo_file(
            std::path::Path::new(path),
            cluster.num_devices(),
        );
    }
    match args.get("graph") {
        Some(path) => TrainingGraph::from_json(&std::fs::read_to_string(path)?),
        None => {
            let opts = bench_opts(args)?;
            let kind = model_of(args)?;
            Ok(disco::models::build(&opts.spec(kind), cluster.num_devices()))
        }
    }
}

fn cmd_plan(args: &Args) -> Result<()> {
    use disco::util::json::Json;
    let cluster_name = args.get_or("cluster", "a");
    let cluster = cluster_of(args);
    let graph = plan_graph(args, &cluster)?;
    let unchanged = args.get_usize("unchanged", 150);
    let seed = args.get_u64("seed", 0xD15C0);
    let estimator = args.get_or("estimator", "analytical").to_string();
    if EstimatorKind::parse(&estimator).is_none() {
        return Err(anyhow!("estimator must be analytical|gnn|oracle (got '{estimator}')"));
    }

    let (source, best_ms, initial_ms, evals, steps_saved, strategy_json) =
        if let Some(addr) = args.get("addr") {
            // Remote mode: ask a running `disco serve`.
            let mut fields = vec![
                ("cmd", Json::Str("plan".into())),
                ("graph", graph.to_json_value()),
                ("cluster", Json::Str(cluster_name.to_string())),
                ("estimator", Json::Str(estimator)),
                // Decimal string: JSON numbers are f64 and would round
                // u64 seeds above 2^53 (the server accepts both forms).
                ("seed", Json::Str(seed.to_string())),
                ("alpha", Json::Num(args.get_f64("alpha", 1.05))),
                ("beta", Json::Num(args.get_usize("beta", 10) as f64)),
                ("unchanged", Json::Num(unchanged as f64)),
            ];
            // Same flags as local mode, forwarded as per-request policy.
            if args.has_flag("no-warm") {
                fields.push(("warm", Json::Bool(false)));
            }
            if args.has_flag("no-nearest") {
                fields.push(("nearest", Json::Bool(false)));
            }
            if args.has_flag("chunking") {
                fields.push(("chunking", Json::Bool(true)));
            }
            if let Some(mc) = args.get("max-chunks") {
                let mc: usize =
                    mc.parse().map_err(|_| anyhow!("--max-chunks must be an integer"))?;
                fields.push(("max_chunks", Json::Num(mc as f64)));
            }
            if args.has_flag("sharding") {
                fields.push(("sharding", Json::Bool(true)));
            }
            let req = Json::obj(fields);
            let resp = disco::service::request(addr, &req)?;
            if resp.get("ok").as_bool() != Some(true) {
                return Err(anyhow!(
                    "server error: {}",
                    resp.get("error").as_str().unwrap_or("unknown")
                ));
            }
            (
                resp.get("source").as_str().unwrap_or("?").to_string(),
                resp.get("best_cost_ms").as_f64().unwrap_or(f64::NAN),
                resp.get("initial_cost_ms").as_f64().unwrap_or(f64::NAN),
                resp.get("evals").as_usize().unwrap_or(0) as u64,
                resp.get("steps_saved").as_usize().unwrap_or(0) as u64,
                resp.get("strategy").clone(),
            )
        } else {
            // Local mode: resolve against the store in-process.
            let device = BenchOptions::device_for(&cluster);
            let store_path = args.get_or("store", "plans.jsonl").to_string();
            let mut store =
                disco::service::open_store(Some(store_path.as_str()), args.get_usize("capacity", 512))?;
            let mut cfg = SearchConfig {
                alpha: args.get_f64("alpha", 1.05),
                beta: args.get_usize("beta", 10),
                unchanged_limit: unchanged,
                seed,
                ..Default::default()
            };
            cfg.track_best_path = true;
            if args.has_flag("chunking") {
                cfg.methods.chunking = true;
            }
            cfg.max_chunks = args.get_usize("max-chunks", cfg.max_chunks as usize) as u32;
            if args.has_flag("sharding") {
                cfg.methods.sharding = true;
            }
            let est_name = if estimator == "analytical" { "analytical" } else { "oracle" };
            // Fingerprint covers the estimator *content* (trained gnn
            // artifact bytes), not just its name — retraining invalidates
            // cached plans (DESIGN.md §11).
            let est_fp =
                disco::service::EstimatorFp::resolve(&estimator, est_name, &Manifest::default_dir());
            let env = disco::service::env_fingerprint(&cluster, &device, &est_fp, &cfg);
            let gfp = disco::service::graph_fingerprint(&graph)
                .map_err(|e| anyhow!("unfingerprintable graph: {e}"))?;
            let key_hex = disco::service::plan_key(gfp, env).hex();
            // Store hits never profile or estimate — check before paying
            // for the profiler (same contract as the server path).
            let hit = store.get(&key_hex).and_then(|rec| {
                disco::service::try_replay_hit(rec, &graph)
                    .map(|best| (rec.best_cost_ms, rec.initial_cost_ms, best))
            });
            if let Some((best_ms, init_ms, best)) = hit {
                ("store".to_string(), best_ms, init_ms, 0, 0, best.to_json_value())
            } else {
                let profile = disco::profiler::profile(&graph, &device, &cluster, 3, cfg.seed);
                let est = if est_name == "analytical" {
                    CostEstimator::analytical(&profile, &cluster)
                } else {
                    CostEstimator::oracle(&profile, &device)
                };
                let warm = disco::service::WarmOptions {
                    enabled: !args.has_flag("no-warm"),
                    nearest: !args.has_flag("no-nearest"),
                    ..Default::default()
                };
                let out =
                    disco::service::plan_with_store(&graph, &est, &cfg, env, &mut store, &warm)?;
                (
                    out.source.name().to_string(),
                    out.best_cost_ms,
                    out.initial_cost_ms,
                    out.evals,
                    out.steps_saved,
                    out.best.to_json_value(),
                )
            }
        };

    println!(
        "plan[{source}] {}: {initial_ms:.3} ms → {best_ms:.3} ms ({:.1}% faster); {evals} evals, {steps_saved} steps saved",
        graph.name,
        (initial_ms / best_ms - 1.0) * 100.0,
    );
    // A chunked plan carries its overlap schedule in the strategy itself
    // (the serialized graph's per-AR "chunk" field) — surface it.
    if let Some(nodes) = strategy_json.get("nodes").as_arr() {
        let sched: Vec<String> = nodes
            .iter()
            .filter(|n| n.get("deleted").as_bool() != Some(true))
            .filter_map(|n| {
                let c = n.get("chunk").as_usize()?;
                Some(format!("{}×{}", n.get("name").as_str().unwrap_or("?"), c))
            })
            .collect();
        if !sched.is_empty() {
            println!("chunk schedule: {}", sched.join(", "));
        }
        // Same for a sharded plan: the per-AR "shard" tag travels in the
        // strategy, so enactment and humans both see which gradients run
        // reduce-scatter/all-gather instead of a whole all-reduce.
        let sharded: Vec<String> = nodes
            .iter()
            .filter(|n| n.get("deleted").as_bool() != Some(true))
            .filter(|n| n.get("shard").as_str() == Some("rs_ag"))
            .map(|n| n.get("name").as_str().unwrap_or("?").to_string())
            .collect();
        if !sharded.is_empty() {
            println!("sharded (reduce-scatter/all-gather): {}", sharded.join(", "));
        }
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, strategy_json.to_string())?;
        println!("wrote optimized strategy to {path}");
    }
    if let Some(expect) = args.get("expect") {
        if expect != source {
            return Err(anyhow!("expected plan source '{expect}', got '{source}'"));
        }
        println!("plan source matched --expect {expect}");
    }
    Ok(())
}

fn cmd_enact(args: &Args) -> Result<()> {
    let path = args.get("strategy").ok_or_else(|| anyhow!("--strategy <file> required"))?;
    let graph = TrainingGraph::from_json(&std::fs::read_to_string(path)?)?;
    let cluster = cluster_of(args);
    let seed = args.get_u64("seed", 0xC0DE);
    // `--chaos "kill@3:1,delay@2:80"` — deterministic fault injection
    // into the in-process workers (grammar in coordinator::fault).
    let fault = match args.get("chaos") {
        Some(spec) => {
            Some(disco::coordinator::FaultPlan::parse(spec, seed).map_err(|e| anyhow!("{e}"))?)
        }
        None => None,
    };
    let trace_path = args.get("trace");
    let cfg = EnactConfig {
        world: args.get_usize("world", 4),
        iterations: args.get_usize("iterations", 10),
        seed,
        device: BenchOptions::device_for(&cluster),
        cluster,
        quorum: args.get_usize("quorum", 0),
        phase_timeout_ms: args.get_u64("timeout-ms", 10_000),
        max_rank_retries: args.get_usize("retries", 1),
        straggler_timeout_ms: args.get_u64("straggler-ms", 0),
        fault,
        trace: trace_path.is_some(),
        ..Default::default()
    };
    let report = enact(&graph, &cfg)?;
    // `--trace out.json` — Chrome-trace timeline: leader phase spans on
    // one lane, one lane per rank (iterations, heartbeats, retire marks).
    if let Some(path) = trace_path {
        let json = disco::util::trace::to_chrome_json(&report.trace_events, &report.trace_tracks);
        std::fs::write(path, json)?;
        println!("wrote enactment trace to {path}");
    }
    println!(
        "enactment: {} workers acked; per-iteration {:.3} ms{}",
        report.acks,
        report.iteration_ms,
        if report.degraded {
            format!(" — DEGRADED (failed ranks {:?})", report.failed_ranks)
        } else {
            String::new()
        }
    );
    for s in &report.status {
        match &s.state {
            disco::coordinator::RankState::Ok => println!(
                "  rank {}: makespan {:.3} ms (comp {:.3}, comm {:.3}; {} reconnects, {} heartbeats)",
                s.rank, s.makespan_ms, s.comp_ms, s.comm_ms, s.reconnects, s.heartbeats
            ),
            disco::coordinator::RankState::Missing => println!("  rank {}: MISSING", s.rank),
            disco::coordinator::RankState::Retired(why) => {
                println!("  rank {}: RETIRED ({why})", s.rank)
            }
        }
    }
    // CI hook: fail unless the run degraded exactly as the injected
    // fault plan predicts.
    if args.has_flag("expect-degraded") && !report.degraded {
        return Err(anyhow!("--expect-degraded: run completed without degradation"));
    }
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args.get("connect").ok_or_else(|| anyhow!("--connect <addr> required"))?;
    let rank = args.get_usize("rank", 0);
    let cluster = cluster_of(args);
    let device = BenchOptions::device_for(&cluster);
    let defaults = disco::coordinator::WorkerOptions::default();
    let opts = disco::coordinator::WorkerOptions {
        io_timeout_ms: args.get_u64("timeout-ms", defaults.io_timeout_ms),
        idle_timeout_ms: args.get_u64("idle-ms", defaults.idle_timeout_ms),
        retry: args.has_flag("retry"),
        max_reconnects: args.get_usize("max-reconnects", defaults.max_reconnects),
        backoff_base_ms: args.get_u64("backoff-ms", defaults.backoff_base_ms),
        backoff_cap_ms: args.get_u64("backoff-cap-ms", defaults.backoff_cap_ms),
        seed: args.get_u64("seed", defaults.seed),
        faults: None,
        trace: None,
    };
    disco::coordinator::run_worker_opts(addr, rank, &device, &cluster, &opts)
}

fn cmd_profile(args: &Args) -> Result<()> {
    let opts = bench_opts(args)?;
    let cluster = cluster_of(args);
    let kind = model_of(args)?;
    let p = disco::bench::prepare(&opts, kind, &cluster);
    println!(
        "{}: {} live ops, {} AllReduces, {:.1}M gradient elements, {:.2} GFLOP/iter",
        kind.name(),
        p.graph.live_count(),
        p.graph.allreduces().len(),
        p.graph.total_gradient_bytes() / 4.0 / 1e6,
        p.graph.total_flops() / 1e9
    );
    println!(
        "comm fit: T = {:.4e}·bytes + {:.3} ms (r² = {:.4}); launch ≈ {:.4} ms; bw ≈ {:.1} GB/s",
        p.profile.comm.c,
        p.profile.comm.d,
        p.profile.comm.r2,
        p.profile.launch_est_ms,
        p.profile.bw_est_bytes_per_ms / 1e6
    );
    let est = CostEstimator::analytical(&p.profile, &p.cluster);
    let sim = p.cost(&p.graph, &est);
    println!(
        "unoptimized per-iteration: {:.3} ms (comp {:.3}, comm {:.3}, overlap {:.2})",
        sim.makespan_ms,
        sim.comp_busy_ms,
        sim.comm_busy_ms,
        sim.overlap_ratio()
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let opts = bench_opts(args)?;
    let what = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let artifacts = Manifest::default_dir();
    let mut sections: Vec<String> = Vec::new();
    let run = |name: &str| what == name || what == "all";
    if run("fig6") || what == "table1" {
        sections.push(experiments::fig6_table1(&opts));
    }
    if run("fig7") {
        sections.push(experiments::fig7(&opts));
    }
    if run("fig8") {
        sections.push(experiments::fig8(&opts));
    }
    if run("fig9") {
        match experiments::fig9(&opts, &artifacts) {
            Ok(s) => sections.push(s),
            Err(e) => eprintln!(
                "fig9 skipped: {e} (interpreter backend bootstraps artifacts \
                 automatically; for PJRT run `make artifacts`)"
            ),
        }
    }
    if run("table2") {
        sections.push(experiments::table2(&opts));
    }
    if run("fig10") {
        sections.push(experiments::fig10(&opts));
    }
    if run("table3") {
        sections.push(experiments::table3(&opts));
    }
    if run("table4") {
        sections.push(experiments::table4(&opts));
    }
    if run("ablation") {
        sections.push(experiments::ablation_estimator(&opts, Some(&artifacts))?);
    }
    if run("extensions") {
        sections.push(experiments::ext_search_ablation(&opts));
        sections.push(experiments::ext_parameter_server(&opts));
        sections.push(experiments::ext_memory(&opts));
    }
    if run("perf") {
        sections.push(experiments::perf_search(&opts));
    }
    if sections.is_empty() {
        return Err(anyhow!("unknown experiment '{what}'"));
    }
    let body = sections.join("\n");
    println!("{body}");
    if let Some(path) = args.get("out") {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        writeln!(
            f,
            "\n<!-- disco bench {what} ({} scale, {} estimator) -->\n{body}",
            if opts.scale == Scale::Full { "full" } else { "fast" },
            opts.estimator.name()
        )?;
        println!("appended to {path}");
    }
    Ok(())
}

fn cmd_train_gnn(args: &Args) -> Result<()> {
    let opts = bench_opts(args)?;
    let artifacts = Manifest::default_dir();
    let per_model = args.get_usize("per-model", 400);
    let epochs = args.get_usize("epochs", 15);
    let report = disco::bench::gnn_pipeline::train_and_eval(
        &opts,
        &artifacts,
        per_model,
        per_model / 4,
        epochs,
    )?;
    let path = disco::bench::gnn_pipeline::save_params(&artifacts, &report.params)?;
    println!(
        "trained on {} samples, {} epochs: loss {:.4} → {:.4}; held-out mean err {:.1}%, within 14%: {:.1}%",
        report.train_samples,
        report.epochs,
        report.first_loss,
        report.last_loss,
        report.mean_error() * 100.0,
        report.frac_within(0.14) * 100.0
    );
    println!("saved trained parameters to {}", path.display());
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    let cfg = TrainConfig {
        artifacts: Manifest::default_dir(),
        world: args.get_usize("workers", 4),
        steps: args.get_usize("steps", 200),
        eval_every: args.get_usize("eval-every", 25),
        seed: args.get_u64("seed", 0x7EA1),
    };
    let res = train_distributed(&cfg)?;
    println!(
        "trained {} params on {} workers for {} steps in {:.1}s",
        res.param_count,
        res.world,
        cfg.steps,
        res.wall_seconds
    );
    for l in res.log.iter().filter(|l| l.step % 10 == 0 || l.eval_loss.is_some()) {
        match l.eval_loss {
            Some(e) => println!("step {:>4}  loss {:.4}  eval {:.4}", l.step, l.loss, e),
            None => println!("step {:>4}  loss {:.4}", l.step, l.loss),
        }
    }
    Ok(())
}

fn cmd_gen_artifacts(args: &Args) -> Result<()> {
    let dir = args
        .get("out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_dir);
    disco::runtime::gen::write_artifacts(&dir)?;
    println!(
        "wrote offline artifact set to {} (HLO text + params + manifest; \
         executable by the in-tree interpreter — DESIGN.md §9)",
        dir.display()
    );
    Ok(())
}

fn cmd_export_samples(args: &Args) -> Result<()> {
    let opts = bench_opts(args)?;
    let per_model = args.get_usize("per-model", 200);
    let out = args.get_or("out", "samples.json");
    let samples = disco::bench::gnn_pipeline::generate_samples(
        &opts,
        per_model,
        args.get_usize("max-group", 24),
        args.get_u64("seed", opts.seed),
    );
    std::fs::write(out, disco::profiler::samples_to_json(&samples))?;
    println!("wrote {} fused-op samples to {out}", samples.len());
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let opts = bench_opts(args)?;
    let cluster = cluster_of(args);
    let kind = model_of(args)?;
    let p = disco::bench::prepare(&opts, kind, &cluster);
    let est = p.estimator(opts.estimator);
    // Optionally trace the optimized module instead of the raw one.
    let graph = if args.has_flag("optimized") {
        backtracking_search(&p.graph, &est, &opts.search_config()).best
    } else {
        p.graph.clone()
    };
    est.prepare(&graph);
    let (res, events) =
        disco::sim::trace::capture(&graph, &est, disco::sim::SimOptions::default());
    let out = args.get_or("out", "trace.json");
    std::fs::write(out, disco::sim::trace::to_chrome_json(&events))?;
    println!(
        "wrote {} events ({:.2} ms makespan, {:.0} MB peak) to {out} — open in chrome://tracing",
        events.len(),
        res.makespan_ms,
        res.peak_bytes / 1e6
    );
    Ok(())
}

/// Execute one HLO text module through the in-tree interpreter — the
/// conformance-corpus authoring loop (DESIGN.md §9). Inputs come from
/// the file's `// input:` directives; actual outputs print as
/// ready-to-paste `// expect:` lines, and any `// expect:` directives
/// already present are verified (non-zero exit on mismatch).
fn cmd_run_hlo(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: disco run-hlo <case.hlo>"))?;
    let text = std::fs::read_to_string(path)?;
    let case = disco::runtime::corpus::parse_case(path, &text)?;
    let verified = !case.expects.is_empty();
    let out = disco::runtime::corpus::run_case(&case)?;
    println!(
        "{path}: {} input(s) → {} output(s){}",
        case.inputs.len(),
        out.len(),
        if verified { "; all expect directives matched" } else { "" }
    );
    for line in disco::runtime::corpus::render_expects(&text, &out) {
        println!("{line}");
    }
    if !verified {
        println!("// (no expect directives present — paste the lines above into {path})");
    }
    Ok(())
}

fn cmd_import_hlo(args: &Args) -> Result<()> {
    let path = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("usage: disco import-hlo <module.hlo.txt> [--optimize]"))?;
    let g = disco::graph::hlo_import::import_hlo_file(std::path::Path::new(path), 1)?;
    println!(
        "{}: {} live instructions, {:.2} GFLOP, {} AllReduces",
        g.name,
        g.live_count(),
        g.total_flops() / 1e9,
        g.allreduces().len()
    );
    let mut kinds: std::collections::BTreeMap<&str, usize> = Default::default();
    for n in g.live() {
        *kinds.entry(n.kind.name()).or_insert(0) += 1;
    }
    for (k, c) in &kinds {
        println!("  {k:<16} {c}");
    }
    if args.has_flag("optimize") {
        let device = disco::device::DeviceModel::gtx1080ti();
        let cluster = Cluster::single_device();
        let prof = disco::profiler::profile(&g, &device, &cluster, 3, 17);
        let est = CostEstimator::oracle(&prof, &device);
        let mut cfg = SearchConfig {
            unchanged_limit: args.get_usize("unchanged", 300),
            ..Default::default()
        };
        cfg.sim.ignore_comm = g.allreduces().is_empty();
        cfg.methods.ar_fusion = !g.allreduces().is_empty();
        let r = backtracking_search(&g, &est, &cfg);
        println!(
            "optimize: {:.3} ms → {:.3} ms ({:.1}% faster; {} evals, {:.1}s)",
            r.initial_cost_ms,
            r.best_cost_ms,
            (r.initial_cost_ms / r.best_cost_ms - 1.0) * 100.0,
            r.evals,
            r.elapsed.as_secs_f64()
        );
    }
    Ok(())
}

const USAGE: &str = "usage: disco <search|serve|store|plan|enact|worker|profile|bench|train-gnn|e2e|import-hlo|run-hlo|gen-artifacts> [options]
  run `disco <cmd> --help` conventions: see rust/src/main.rs module docs";

fn main() {
    let args = Args::from_env();
    // `--backend interp|pjrt` selects the runtime engine for this process
    // (read by BackendKind::from_env at every Runtime construction).
    if let Some(b) = args.get("backend") {
        if disco::runtime::BackendKind::parse(b).is_none() {
            eprintln!("error: unknown backend '{b}' (expected interp|pjrt)");
            std::process::exit(2);
        }
        std::env::set_var("DISCO_BACKEND", b);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "search" => cmd_search(&args),
        "serve" => cmd_serve(&args),
        "store" => cmd_store(&args),
        "plan" => cmd_plan(&args),
        "enact" => cmd_enact(&args),
        "worker" => cmd_worker(&args),
        "profile" => cmd_profile(&args),
        "bench" => cmd_bench(&args),
        "train-gnn" => cmd_train_gnn(&args),
        "e2e" => cmd_e2e(&args),
        "import-hlo" => cmd_import_hlo(&args),
        "run-hlo" => cmd_run_hlo(&args),
        "gen-artifacts" => cmd_gen_artifacts(&args),
        "export-samples" => cmd_export_samples(&args),
        "trace" => cmd_trace(&args),
        _ => {
            let _ = build; // silence unused in non-model paths
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
