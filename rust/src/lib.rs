//! # disco-rs
//!
//! A reproduction of **DisCo** — *"Optimizing DNN Compilation for Distributed
//! Training with Joint OP and Tensor Fusion"* (Yi et al., TPDS 2022) — as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! DisCo takes a training graph (our HLO-like IR, [`graph::TrainingGraph`]),
//! and jointly searches over
//!
//! * **computation op fusion** (non-duplicate and duplicate, [`fusion`]),
//! * **communication tensor fusion** (combining AllReduce instructions),
//!
//! to minimize per-iteration distributed training time. The search
//! ([`search`], Alg. 1 of the paper) is driven by a discrete-event
//! [`sim`]ulator whose fused-op costs come from a [`estimator`] — either an
//! analytical model or the paper's GNN *Fused Op Estimator*, executed as an
//! AOT-compiled HLO artifact through [`runtime`] (an in-tree HLO
//! interpreter by default; PJRT when a real `xla` binding is present).
//!
//! The distributed substrate the paper assumes (GPU cluster + NCCL) is
//! replaced by an analytical [`device`] model, a ring-AllReduce [`network`]
//! model, and a real in-process [`collective`] used for actual gradient
//! averaging in the end-to-end example. See `DESIGN.md` for the full
//! substitution table.
//!
//! Search results are pure functions of their inputs, so the [`service`]
//! layer turns the compiler into a long-running, cache-amortized server:
//! strategies are stored under canonical content fingerprints, identical
//! requests replay the cached plan without simulating, and similar
//! requests warm-start the search (`disco serve` / `disco plan`).
//!
//! ## Quick tour
//!
//! ```no_run
//! use disco::prelude::*;
//!
//! // 1. A workload: transformer training graph for 12 workers.
//! let spec = disco::models::ModelSpec::transformer_base();
//! let graph = disco::models::build(&spec, 12);
//!
//! // 2. A testbed: cluster A from the paper (6x2 GTX-1080-Ti-like).
//! let cluster = disco::network::Cluster::cluster_a();
//! let device = disco::device::DeviceModel::gtx1080ti();
//!
//! // 3. Profile + search.
//! let profile = disco::profiler::profile(&graph, &device, &cluster, 3, 7);
//! let est = disco::estimator::CostEstimator::analytical(&profile, &cluster);
//! let cfg = disco::search::SearchConfig::default();
//! let result = disco::search::backtracking_search(&graph, &est, &cfg);
//! println!("optimized per-iteration time: {:.3} ms", result.best_cost_ms);
//! ```

pub mod util;
pub mod xla_stub;
pub mod graph;
pub mod device;
pub mod network;
pub mod models;
pub mod profiler;
pub mod fusion;
pub mod estimator;
pub mod sim;
pub mod search;
pub mod service;
pub mod baselines;
pub mod collective;
pub mod runtime;
pub mod coordinator;
pub mod bench;

/// Commonly used types, re-exported for examples and binaries.
pub mod prelude {
    pub use crate::device::DeviceModel;
    pub use crate::estimator::CostEstimator;
    pub use crate::graph::{DType, Node, OpKind, Shape, TrainingGraph};
    pub use crate::models::ModelSpec;
    pub use crate::network::Cluster;
    pub use crate::search::{backtracking_search, SearchConfig};
    pub use crate::sim::{simulate, SimOptions, SimWorkspace};
    pub use crate::util::rng::Rng;
}
