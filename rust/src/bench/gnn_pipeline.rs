//! GNN estimator pipeline: generate fused-op samples from the model zoo,
//! train the estimator through the runtime's train-step artifact (the
//! in-tree interpreter by default — fully offline, bootstrapping the
//! artifact set if needed; DESIGN.md §9), and evaluate prediction error
//! on held-out fused ops (paper §6.5 / Fig. 9).

use super::BenchOptions;
use crate::estimator::AnalyticalFused;
use crate::models::{self, ModelKind};
use crate::network::Cluster;
use crate::profiler::{self, FusedSample};
use crate::runtime::gnn::{GnnPredictor, GnnTrainer};
use crate::runtime::Runtime;
use crate::util::stats::{percentile, Histogram};
use anyhow::Result;
use std::path::Path;

/// Outcome of the Fig. 9 experiment.
pub struct GnnEvalReport {
    pub train_samples: usize,
    pub test_samples: usize,
    pub epochs: usize,
    pub first_loss: f64,
    pub last_loss: f64,
    /// Relative errors |pred − real| / real on the held-out set.
    pub errors: Vec<f64>,
    /// PDF/CDF histogram of the errors (30 bins over [0, 0.6)).
    pub hist: Histogram,
    /// Trained flat parameters (savable via `save_params`).
    pub params: Vec<f32>,
}

impl GnnEvalReport {
    pub fn frac_within(&self, tol: f64) -> f64 {
        if self.errors.is_empty() {
            return 0.0;
        }
        self.errors.iter().filter(|&&e| e <= tol).count() as f64 / self.errors.len() as f64
    }

    pub fn mean_error(&self) -> f64 {
        crate::util::stats::mean(&self.errors)
    }

    pub fn p90_error(&self) -> f64 {
        percentile(&self.errors, 90.0)
    }
}

/// Generate per-model fused-op samples (paper §5.2: random predecessor
/// fusion chains) with device-model labels.
pub fn generate_samples(
    opts: &BenchOptions,
    per_model: usize,
    max_group: usize,
    seed: u64,
) -> Vec<FusedSample> {
    let cluster = Cluster::cluster_a();
    let device = BenchOptions::device_for(&cluster);
    let mut all = Vec::new();
    for kind in ModelKind::ALL {
        let g = models::build(&opts.spec(kind), cluster.num_devices());
        let prof = profiler::profile(&g, &device, &cluster, 2, seed ^ kind as u64);
        let samples = profiler::generate_fused_samples(
            &g,
            &device,
            &prof,
            per_model,
            max_group,
            seed.wrapping_mul(31).wrapping_add(kind as u64),
        );
        all.extend(samples);
    }
    all
}

/// Train the GNN on `train_per_model` samples per model, evaluate on
/// `test_per_model` *unseen* samples per model.
pub fn train_and_eval(
    opts: &BenchOptions,
    artifacts: &Path,
    train_per_model: usize,
    test_per_model: usize,
    epochs: usize,
) -> Result<GnnEvalReport> {
    let rt = Runtime::new(artifacts)?;
    // Disjoint seeds → disjoint random fusion chains for train vs test.
    let train = generate_samples(opts, train_per_model, 24, opts.seed ^ 0x7124);
    let test = generate_samples(opts, test_per_model, 24, opts.seed ^ 0x7E57);

    let mut trainer = GnnTrainer::new(&rt)?;
    let losses = trainer.train(&train, epochs)?;
    let first_loss = losses.first().copied().unwrap_or(0.0);
    let last_loss = losses.last().copied().unwrap_or(0.0);

    let fallback = AnalyticalFused { launch_ms: 0.005, bw_bytes_per_ms: 4.8e8 };
    let pred = GnnPredictor::with_params(&rt, trainer.params.clone(), fallback)?;
    let items: Vec<_> = test
        .iter()
        .map(|s| (s.group.clone(), s.bytes_in, s.bytes_out))
        .collect();
    let preds = pred.predict(&items)?;
    let mut errors = Vec::with_capacity(test.len());
    let mut hist = Histogram::new(0.0, 0.6, 30);
    for (s, p) in test.iter().zip(&preds) {
        let e = (p - s.label_ms).abs() / s.label_ms.max(1e-9);
        errors.push(e);
        hist.add(e);
    }
    Ok(GnnEvalReport {
        train_samples: train.len(),
        test_samples: test.len(),
        epochs,
        first_loss,
        last_loss,
        errors,
        hist,
        params: trainer.params,
    })
}

/// Persist trained estimator parameters next to the artifacts.
pub fn save_params(artifacts: &Path, params: &[f32]) -> Result<std::path::PathBuf> {
    let path = artifacts.join("gnn_trained.f32");
    let mut bytes = Vec::with_capacity(params.len() * 4);
    for p in params {
        bytes.extend_from_slice(&p.to_le_bytes());
    }
    std::fs::write(&path, bytes)?;
    Ok(path)
}

/// Load previously trained parameters if present.
pub fn load_trained_params(artifacts: &Path) -> Option<Vec<f32>> {
    let bytes = std::fs::read(artifacts.join("gnn_trained.f32")).ok()?;
    Some(
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
    )
}
