//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation section (§6) as markdown tables (see DESIGN.md §5 for the
//! experiment index). The `disco bench <exp>` CLI drives these.
//!
//! Scale: `Scale::Full` uses the published model architectures and paper
//! hyper-parameters (α = 1.05, β = 10, unchanged limit 1000); CI and quick
//! runs use `Scale::Fast` (quarter-depth models, smaller search budget).
//! Absolute milliseconds live on our simulated testbed, not the authors'
//! GPUs — the reproduction target is the *shape*: who wins, by roughly
//! what factor, where the crossovers fall (see EXPERIMENTS.md).

pub mod experiments;
pub mod gnn_pipeline;

use crate::baselines;
use crate::device::DeviceModel;
use crate::estimator::CostEstimator;
use crate::graph::TrainingGraph;
use crate::models::{self, ModelKind, ModelSpec};
use crate::network::Cluster;
use crate::profiler::{self, ProfileData};
use crate::search::{backtracking_search, MethodSet, SearchConfig, SearchResult};
use crate::sim::{fo_bound, simulate, CostSource, SimOptions, SimResult};

/// Benchmark scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Published architectures, paper search budget.
    Full,
    /// Quarter-depth models, reduced search budget (CI-friendly).
    Fast,
}

/// Which fused-op estimator backs the search cost model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EstimatorKind {
    /// White-box heuristic from profiled quantities (no GNN).
    Analytical,
    /// The GNN Fused-Op Estimator via PJRT (paper §4.3). Trained on
    /// profiler-generated samples before use.
    Gnn,
    /// Device-model ground truth (upper bound; not available to a real
    /// system — ablations only).
    Oracle,
}

impl EstimatorKind {
    pub fn parse(s: &str) -> Option<EstimatorKind> {
        match s {
            "analytical" => Some(EstimatorKind::Analytical),
            "gnn" => Some(EstimatorKind::Gnn),
            "oracle" => Some(EstimatorKind::Oracle),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EstimatorKind::Analytical => "analytical",
            EstimatorKind::Gnn => "gnn",
            EstimatorKind::Oracle => "oracle",
        }
    }
}

/// Everything a benchmark run needs.
#[derive(Debug, Clone)]
pub struct BenchOptions {
    pub scale: Scale,
    pub estimator: EstimatorKind,
    pub seed: u64,
    pub alpha: f64,
    pub beta: usize,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions {
            scale: Scale::Fast,
            estimator: EstimatorKind::Analytical,
            seed: 0xD15C0,
            alpha: 1.05,
            beta: 10,
        }
    }
}

impl BenchOptions {
    pub fn spec(&self, kind: ModelKind) -> ModelSpec {
        let mut spec = match kind {
            ModelKind::Vgg19 => ModelSpec::vgg19(),
            ModelKind::ResNet50 => ModelSpec::resnet50(),
            ModelKind::Transformer => ModelSpec::transformer_base(),
            ModelKind::Rnnlm => ModelSpec::rnnlm(),
            ModelKind::Bert => ModelSpec::bert_base(),
            ModelKind::Reformer => ModelSpec::reformer(),
        };
        if self.scale == Scale::Fast {
            spec.depth_scale = 0.25;
            spec.batch = (spec.batch / 2).max(4);
        }
        spec
    }

    pub fn search_config(&self) -> SearchConfig {
        SearchConfig {
            alpha: self.alpha,
            beta: self.beta,
            unchanged_limit: match self.scale {
                Scale::Full => 1000,
                Scale::Fast => 150,
            },
            max_queue: 256,
            max_seconds: 0.0,
            methods: MethodSet::all(),
            sim: SimOptions::default(),
            seed: self.seed,
            ..SearchConfig::default()
        }
    }

    /// Device model for a cluster (A → 1080Ti, B → T4).
    pub fn device_for(cluster: &Cluster) -> DeviceModel {
        if cluster.name == "B" {
            DeviceModel::tesla_t4()
        } else {
            DeviceModel::gtx1080ti()
        }
    }
}

/// Build + profile one model on a cluster.
pub struct Prepared {
    pub kind: ModelKind,
    pub graph: TrainingGraph,
    pub device: DeviceModel,
    pub cluster: Cluster,
    pub profile: ProfileData,
}

pub fn prepare(opts: &BenchOptions, kind: ModelKind, cluster: &Cluster) -> Prepared {
    let device = BenchOptions::device_for(cluster);
    let graph = models::build(&opts.spec(kind), cluster.num_devices());
    let profile = profiler::profile(&graph, &device, cluster, 3, opts.seed ^ kind as u64);
    Prepared { kind, graph, device, cluster: cluster.clone(), profile }
}

impl Prepared {
    /// Estimator of the requested kind. GNN needs pretrained params —
    /// callers that want the GNN path use [`gnn_pipeline`] to obtain a
    /// predictor and construct the estimator themselves; here Gnn falls
    /// back to Oracle so table harnesses remain runnable without
    /// artifacts.
    pub fn estimator(&self, kind: EstimatorKind) -> CostEstimator<'_> {
        match kind {
            EstimatorKind::Analytical => CostEstimator::analytical(&self.profile, &self.cluster),
            EstimatorKind::Oracle | EstimatorKind::Gnn => {
                CostEstimator::oracle(&self.profile, &self.device)
            }
        }
    }

    pub fn cost(&self, graph: &TrainingGraph, est: &CostEstimator<'_>) -> SimResult {
        est.prepare(graph);
        simulate(graph, est, SimOptions::default())
    }
}

/// One scheme's outcome on one (model, cluster).
#[derive(Debug, Clone)]
pub struct SchemeResult {
    pub scheme: &'static str,
    pub sim: SimResult,
}

/// Run every baseline scheme + DisCo + the FO bound. Returns results in
/// presentation order (paper Fig. 6 legend order).
pub fn run_all_schemes(p: &Prepared, opts: &BenchOptions) -> (Vec<SchemeResult>, SearchResult) {
    let est = p.estimator(opts.estimator);
    let mut out = Vec::new();
    let schemes: Vec<(&'static str, TrainingGraph)> = vec![
        ("JAX_no_fusion", baselines::no_fusion(&p.graph)),
        ("JAX_op_fusion", baselines::xla_op_fusion(&p.graph)),
        (
            "JAX_AllReduce_fusion",
            baselines::ar_threshold_fusion(&p.graph, baselines::XLA_AR_THRESHOLD),
        ),
        ("JAX_default", baselines::jax_default(&p.graph)),
        ("PyTorch_DDP", baselines::pytorch_ddp(&p.graph)),
    ];
    for (name, g) in &schemes {
        out.push(SchemeResult { scheme: name, sim: p.cost(g, &est) });
    }
    let result = backtracking_search(&p.graph, &est, &opts.search_config());
    out.push(SchemeResult { scheme: "DisCo", sim: p.cost(&result.best, &est) });
    // FO lower bound, per the paper: full overlap of the best module's
    // computation and communication.
    let fo = fo_bound(&result.best, &est);
    out.push(SchemeResult {
        scheme: "FO",
        sim: SimResult {
            makespan_ms: fo,
            comp_busy_ms: 0.0,
            comm_busy_ms: 0.0,
            comp_idle_ms: 0.0,
            comm_idle_ms: 0.0,
            kernels: 0,
            allreduces: 0,
            peak_bytes: 0.0,
        },
    });
    (out, result)
}

// ---------------------------------------------------------------------------
// Search hot-path A/B perf record (BENCH_search.json).
// ---------------------------------------------------------------------------

/// One engine configuration's measured throughput on the record workload.
#[derive(Debug, Clone)]
pub struct HotPathModeStats {
    pub evals: u64,
    pub steps: u64,
    /// Checkpointed parent re-simulations (delta-sim arm only).
    pub resims: u64,
    pub seconds: f64,
    pub evals_per_sec: f64,
    pub peak_arena_bytes: usize,
    pub best_cost_ms: f64,
    /// Estimator prediction-memo counters for the arm's run.
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
}

/// Three-arm measurement of the search hot path on the acceptance
/// workload (`transformer_base`, 12 workers — paper cluster A).
/// "Before" pins the PR-0 engine behavior through the [`SearchConfig`]
/// toggles (eager full-clone arena, fresh scratch allocations per eval,
/// full candidate re-enumeration per mutation, serial evaluation);
/// "after" is the PR-1 allocation-free engine with full per-candidate
/// simulation; "delta" adds flat cost tables + checkpointed delta
/// simulation (the current default engine).
#[derive(Debug, Clone)]
pub struct HotPathRecord {
    pub model: &'static str,
    pub workers: usize,
    pub unchanged_limit: usize,
    pub seed: u64,
    pub before: HotPathModeStats,
    pub after: HotPathModeStats,
    pub delta: HotPathModeStats,
}

impl HotPathRecord {
    pub fn throughput_ratio(&self) -> f64 {
        if self.before.evals_per_sec == 0.0 {
            0.0
        } else {
            self.after.evals_per_sec / self.before.evals_per_sec
        }
    }

    /// Delta-sim arm vs the PR-1 "after" arm (the ISSUE 3 acceptance
    /// metric: ≥ 2× further evals/sec).
    pub fn delta_ratio(&self) -> f64 {
        if self.after.evals_per_sec == 0.0 {
            0.0
        } else {
            self.delta.evals_per_sec / self.after.evals_per_sec
        }
    }

    pub fn arena_ratio(&self) -> f64 {
        if self.after.peak_arena_bytes == 0 {
            0.0
        } else {
            self.before.peak_arena_bytes as f64 / self.after.peak_arena_bytes as f64
        }
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mode = |m: &HotPathModeStats| {
            Json::obj(vec![
                ("evals", Json::Num(m.evals as f64)),
                ("steps", Json::Num(m.steps as f64)),
                ("resims", Json::Num(m.resims as f64)),
                ("seconds", Json::Num(m.seconds)),
                ("evals_per_sec", Json::Num(m.evals_per_sec)),
                ("peak_arena_bytes", Json::Num(m.peak_arena_bytes as f64)),
                ("best_cost_ms", Json::Num(m.best_cost_ms)),
                ("cache_hits", Json::Num(m.cache_hits as f64)),
                ("cache_misses", Json::Num(m.cache_misses as f64)),
                ("cache_evictions", Json::Num(m.cache_evictions as f64)),
            ])
        };
        Json::obj(vec![
            ("bench", Json::Str("search_hot_path".into())),
            ("model", Json::Str(self.model.into())),
            ("workers", Json::Num(self.workers as f64)),
            ("unchanged_limit", Json::Num(self.unchanged_limit as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("measured", Json::Bool(true)),
            ("before", mode(&self.before)),
            ("after", mode(&self.after)),
            ("delta", mode(&self.delta)),
            ("evals_per_sec_ratio", Json::Num(self.throughput_ratio())),
            ("delta_evals_per_sec_ratio", Json::Num(self.delta_ratio())),
            ("peak_arena_bytes_ratio", Json::Num(self.arena_ratio())),
        ])
    }
}

fn timed_search(
    graph: &TrainingGraph,
    est: &CostEstimator<'_>,
    cfg: &SearchConfig,
) -> HotPathModeStats {
    let t = std::time::Instant::now();
    let r = backtracking_search(graph, est, cfg);
    let seconds = t.elapsed().as_secs_f64();
    let cache = est.cache_detail();
    HotPathModeStats {
        evals: r.evals,
        steps: r.steps,
        resims: r.resims,
        seconds,
        evals_per_sec: if seconds > 0.0 { r.evals as f64 / seconds } else { 0.0 },
        peak_arena_bytes: r.peak_arena_bytes,
        best_cost_ms: r.best_cost_ms,
        cache_hits: cache.hits,
        cache_misses: cache.misses,
        cache_evictions: cache.evictions,
    }
}

/// Measure the search hot path (before / after / delta) on the acceptance
/// workload. Always uses the *full* `transformer_base` spec (the record
/// is about engine throughput, not CI speed); `opts.scale` only sizes the
/// budget.
pub fn search_hot_path_record(opts: &BenchOptions) -> HotPathRecord {
    let cluster = Cluster::cluster_a();
    let device = BenchOptions::device_for(&cluster);
    let graph = models::build(&ModelSpec::transformer_base(), cluster.num_devices());
    let profile = profiler::profile(&graph, &device, &cluster, 2, opts.seed);
    let unchanged_limit = match opts.scale {
        Scale::Full => 400,
        Scale::Fast => 150,
    };
    let base = SearchConfig { unchanged_limit, seed: opts.seed, ..Default::default() };
    let before_cfg = SearchConfig {
        eval_threads: 1,
        delta_candidates: false,
        reuse_workspaces: false,
        incremental_candidates: false,
        cost_table: false,
        delta_sim: false,
        ..base.clone()
    };
    // PR-1 engine: everything allocation-free, but every candidate fully
    // simulated with per-event dyn-dispatched costs.
    let after_cfg = SearchConfig { cost_table: false, delta_sim: false, ..base.clone() };
    // Fresh estimator (cold prediction memo) and fresh graph (cold CSR
    // cache) per arm — sharing them would hand a later run a pre-warmed
    // cache and bias the throughput ratios by run order.
    let before = {
        let est = CostEstimator::oracle(&profile, &device);
        timed_search(&graph.clone(), &est, &before_cfg)
    };
    let after = {
        let est = CostEstimator::oracle(&profile, &device);
        timed_search(&graph.clone(), &est, &after_cfg)
    };
    let delta = {
        let est = CostEstimator::oracle(&profile, &device);
        timed_search(&graph.clone(), &est, &base)
    };
    HotPathRecord {
        model: "transformer_base",
        workers: cluster.num_devices(),
        unchanged_limit,
        seed: opts.seed,
        before,
        after,
        delta,
    }
}

/// Repository root (the parent of the `rust/` crate), resolved at compile
/// time so the record lands in the same place regardless of cwd.
pub fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."))
}

/// Run the A/B measurement and write `BENCH_search.json` at the repo root.
/// Returns the record and the path written.
pub fn write_search_perf_record(
    opts: &BenchOptions,
) -> std::io::Result<(HotPathRecord, std::path::PathBuf)> {
    let record = search_hot_path_record(opts);
    let path = repo_root().join("BENCH_search.json");
    std::fs::write(&path, record.to_json().to_string())?;
    Ok((record, path))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_all_schemes_ordering_and_sanity() {
        let opts = BenchOptions { scale: Scale::Fast, ..Default::default() };
        let cluster = Cluster::cluster_a();
        let p = prepare(&opts, ModelKind::Rnnlm, &cluster);
        let (schemes, result) = run_all_schemes(&p, &opts);
        assert_eq!(schemes.len(), 7);
        assert_eq!(schemes[0].scheme, "JAX_no_fusion");
        assert_eq!(schemes[5].scheme, "DisCo");
        assert_eq!(schemes[6].scheme, "FO");
        let disco = schemes[5].sim.makespan_ms;
        let fo = schemes[6].sim.makespan_ms;
        let best_baseline = schemes[..5]
            .iter()
            .map(|s| s.sim.makespan_ms)
            .fold(f64::INFINITY, f64::min);
        assert!(disco <= best_baseline * 1.05, "disco {disco} vs baseline {best_baseline}");
        assert!(disco >= fo * 0.999, "disco {disco} below FO {fo}");
        assert!(result.best.validate().is_ok());
    }

    #[test]
    fn forward_only_strips_backward() {
        let g = models::build(&ModelSpec { kind: ModelKind::Rnnlm, batch: 8, depth_scale: 0.2 }, 4);
        let f = g.forward_only();
        assert!(f.validate().is_ok());
        assert!(f.allreduces().is_empty());
        assert!(f.live_count() < g.live_count());
    }
}
