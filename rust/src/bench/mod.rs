//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation section (§6) as markdown tables (see DESIGN.md §5 for the
//! experiment index). The `disco bench <exp>` CLI drives these.
//!
//! Scale: `Scale::Full` uses the published model architectures and paper
//! hyper-parameters (α = 1.05, β = 10, unchanged limit 1000); CI and quick
//! runs use `Scale::Fast` (quarter-depth models, smaller search budget).
//! Absolute milliseconds live on our simulated testbed, not the authors'
//! GPUs — the reproduction target is the *shape*: who wins, by roughly
//! what factor, where the crossovers fall (see EXPERIMENTS.md).

pub mod experiments;
pub mod gnn_pipeline;

use crate::baselines;
use crate::device::DeviceModel;
use crate::estimator::CostEstimator;
use crate::graph::TrainingGraph;
use crate::models::{self, ModelKind, ModelSpec};
use crate::network::Cluster;
use crate::profiler::{self, ProfileData};
use crate::search::{backtracking_search, MethodSet, SearchConfig, SearchResult};
use crate::sim::{fo_bound, simulate, CostSource, SimOptions, SimResult};

/// Benchmark scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Published architectures, paper search budget.
    Full,
    /// Quarter-depth models, reduced search budget (CI-friendly).
    Fast,
}

/// Which fused-op estimator backs the search cost model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EstimatorKind {
    /// White-box heuristic from profiled quantities (no GNN).
    Analytical,
    /// The GNN Fused-Op Estimator via PJRT (paper §4.3). Trained on
    /// profiler-generated samples before use.
    Gnn,
    /// Device-model ground truth (upper bound; not available to a real
    /// system — ablations only).
    Oracle,
}

impl EstimatorKind {
    pub fn parse(s: &str) -> Option<EstimatorKind> {
        match s {
            "analytical" => Some(EstimatorKind::Analytical),
            "gnn" => Some(EstimatorKind::Gnn),
            "oracle" => Some(EstimatorKind::Oracle),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EstimatorKind::Analytical => "analytical",
            EstimatorKind::Gnn => "gnn",
            EstimatorKind::Oracle => "oracle",
        }
    }
}

/// Everything a benchmark run needs.
#[derive(Debug, Clone)]
pub struct BenchOptions {
    pub scale: Scale,
    pub estimator: EstimatorKind,
    pub seed: u64,
    pub alpha: f64,
    pub beta: usize,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions {
            scale: Scale::Fast,
            estimator: EstimatorKind::Analytical,
            seed: 0xD15C0,
            alpha: 1.05,
            beta: 10,
        }
    }
}

impl BenchOptions {
    pub fn spec(&self, kind: ModelKind) -> ModelSpec {
        let mut spec = match kind {
            ModelKind::Vgg19 => ModelSpec::vgg19(),
            ModelKind::ResNet50 => ModelSpec::resnet50(),
            ModelKind::Transformer => ModelSpec::transformer_base(),
            ModelKind::Rnnlm => ModelSpec::rnnlm(),
            ModelKind::Bert => ModelSpec::bert_base(),
            ModelKind::Reformer => ModelSpec::reformer(),
        };
        if self.scale == Scale::Fast {
            spec.depth_scale = 0.25;
            spec.batch = (spec.batch / 2).max(4);
        }
        spec
    }

    pub fn search_config(&self) -> SearchConfig {
        SearchConfig {
            alpha: self.alpha,
            beta: self.beta,
            unchanged_limit: match self.scale {
                Scale::Full => 1000,
                Scale::Fast => 150,
            },
            max_queue: 256,
            max_seconds: 0.0,
            methods: MethodSet::all(),
            sim: SimOptions::default(),
            seed: self.seed,
            ..SearchConfig::default()
        }
    }

    /// Device model for a cluster (A → 1080Ti, B → T4).
    pub fn device_for(cluster: &Cluster) -> DeviceModel {
        if cluster.name == "B" {
            DeviceModel::tesla_t4()
        } else {
            DeviceModel::gtx1080ti()
        }
    }
}

/// Build + profile one model on a cluster.
pub struct Prepared {
    pub kind: ModelKind,
    pub graph: TrainingGraph,
    pub device: DeviceModel,
    pub cluster: Cluster,
    pub profile: ProfileData,
}

pub fn prepare(opts: &BenchOptions, kind: ModelKind, cluster: &Cluster) -> Prepared {
    let device = BenchOptions::device_for(cluster);
    let graph = models::build(&opts.spec(kind), cluster.num_devices());
    let profile = profiler::profile(&graph, &device, cluster, 3, opts.seed ^ kind as u64);
    Prepared { kind, graph, device, cluster: cluster.clone(), profile }
}

impl Prepared {
    /// Estimator of the requested kind. GNN needs pretrained params —
    /// callers that want the GNN path use [`gnn_pipeline`] to obtain a
    /// predictor and construct the estimator themselves; here Gnn falls
    /// back to Oracle so table harnesses remain runnable without
    /// artifacts.
    pub fn estimator(&self, kind: EstimatorKind) -> CostEstimator<'_> {
        match kind {
            EstimatorKind::Analytical => CostEstimator::analytical(&self.profile, &self.cluster),
            EstimatorKind::Oracle | EstimatorKind::Gnn => {
                CostEstimator::oracle(&self.profile, &self.device)
            }
        }
    }

    pub fn cost(&self, graph: &TrainingGraph, est: &CostEstimator<'_>) -> SimResult {
        est.prepare(graph);
        simulate(graph, est, SimOptions::default())
    }
}

/// One scheme's outcome on one (model, cluster).
#[derive(Debug, Clone)]
pub struct SchemeResult {
    pub scheme: &'static str,
    pub sim: SimResult,
}

/// Run every baseline scheme + DisCo + the FO bound. Returns results in
/// presentation order (paper Fig. 6 legend order).
pub fn run_all_schemes(p: &Prepared, opts: &BenchOptions) -> (Vec<SchemeResult>, SearchResult) {
    let est = p.estimator(opts.estimator);
    let mut out = Vec::new();
    let schemes: Vec<(&'static str, TrainingGraph)> = vec![
        ("JAX_no_fusion", baselines::no_fusion(&p.graph)),
        ("JAX_op_fusion", baselines::xla_op_fusion(&p.graph)),
        (
            "JAX_AllReduce_fusion",
            baselines::ar_threshold_fusion(&p.graph, baselines::XLA_AR_THRESHOLD),
        ),
        ("JAX_default", baselines::jax_default(&p.graph)),
        ("PyTorch_DDP", baselines::pytorch_ddp(&p.graph)),
    ];
    for (name, g) in &schemes {
        out.push(SchemeResult { scheme: name, sim: p.cost(g, &est) });
    }
    let result = backtracking_search(&p.graph, &est, &opts.search_config());
    out.push(SchemeResult { scheme: "DisCo", sim: p.cost(&result.best, &est) });
    // FO lower bound, per the paper: full overlap of the best module's
    // computation and communication.
    let fo = fo_bound(&result.best, &est);
    out.push(SchemeResult {
        scheme: "FO",
        sim: SimResult {
            makespan_ms: fo,
            comp_busy_ms: 0.0,
            comm_busy_ms: 0.0,
            comp_idle_ms: 0.0,
            comm_idle_ms: 0.0,
            kernels: 0,
            allreduces: 0,
            peak_bytes: 0.0,
        },
    });
    (out, result)
}

// ---------------------------------------------------------------------------
// Search hot-path A/B perf record (BENCH_search.json).
// ---------------------------------------------------------------------------

/// One engine configuration's measured throughput on the record workload.
#[derive(Debug, Clone)]
pub struct HotPathModeStats {
    pub evals: u64,
    pub steps: u64,
    /// Checkpointed parent re-simulations (delta-sim arm only).
    pub resims: u64,
    pub seconds: f64,
    pub evals_per_sec: f64,
    pub peak_arena_bytes: usize,
    pub best_cost_ms: f64,
    /// Estimator prediction-memo counters for the arm's run.
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
}

/// Three-arm measurement of the search hot path on the acceptance
/// workload (`transformer_base`, 12 workers — paper cluster A).
/// "Before" pins the PR-0 engine behavior through the [`SearchConfig`]
/// toggles (eager full-clone arena, fresh scratch allocations per eval,
/// full candidate re-enumeration per mutation, serial evaluation);
/// "after" is the PR-1 allocation-free engine with full per-candidate
/// simulation; "delta" adds flat cost tables + checkpointed delta
/// simulation (the current default engine).
#[derive(Debug, Clone)]
pub struct HotPathRecord {
    pub model: &'static str,
    pub workers: usize,
    pub unchanged_limit: usize,
    pub seed: u64,
    pub before: HotPathModeStats,
    pub after: HotPathModeStats,
    pub delta: HotPathModeStats,
}

impl HotPathRecord {
    pub fn throughput_ratio(&self) -> f64 {
        if self.before.evals_per_sec == 0.0 {
            0.0
        } else {
            self.after.evals_per_sec / self.before.evals_per_sec
        }
    }

    /// Delta-sim arm vs the PR-1 "after" arm (the ISSUE 3 acceptance
    /// metric: ≥ 2× further evals/sec).
    pub fn delta_ratio(&self) -> f64 {
        if self.after.evals_per_sec == 0.0 {
            0.0
        } else {
            self.delta.evals_per_sec / self.after.evals_per_sec
        }
    }

    pub fn arena_ratio(&self) -> f64 {
        if self.after.peak_arena_bytes == 0 {
            0.0
        } else {
            self.before.peak_arena_bytes as f64 / self.after.peak_arena_bytes as f64
        }
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mode = |m: &HotPathModeStats| {
            Json::obj(vec![
                ("evals", Json::Num(m.evals as f64)),
                ("steps", Json::Num(m.steps as f64)),
                ("resims", Json::Num(m.resims as f64)),
                ("seconds", Json::Num(m.seconds)),
                ("evals_per_sec", Json::Num(m.evals_per_sec)),
                ("peak_arena_bytes", Json::Num(m.peak_arena_bytes as f64)),
                ("best_cost_ms", Json::Num(m.best_cost_ms)),
                ("cache_hits", Json::Num(m.cache_hits as f64)),
                ("cache_misses", Json::Num(m.cache_misses as f64)),
                ("cache_evictions", Json::Num(m.cache_evictions as f64)),
            ])
        };
        Json::obj(vec![
            ("bench", Json::Str("search_hot_path".into())),
            ("model", Json::Str(self.model.into())),
            ("workers", Json::Num(self.workers as f64)),
            ("unchanged_limit", Json::Num(self.unchanged_limit as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("measured", Json::Bool(true)),
            ("before", mode(&self.before)),
            ("after", mode(&self.after)),
            ("delta", mode(&self.delta)),
            ("evals_per_sec_ratio", Json::Num(self.throughput_ratio())),
            ("delta_evals_per_sec_ratio", Json::Num(self.delta_ratio())),
            ("peak_arena_bytes_ratio", Json::Num(self.arena_ratio())),
        ])
    }
}

fn timed_search(
    graph: &TrainingGraph,
    est: &CostEstimator<'_>,
    cfg: &SearchConfig,
) -> HotPathModeStats {
    let t = std::time::Instant::now();
    let r = backtracking_search(graph, est, cfg);
    let seconds = t.elapsed().as_secs_f64();
    let cache = est.cache_detail();
    HotPathModeStats {
        evals: r.evals,
        steps: r.steps,
        resims: r.resims,
        seconds,
        evals_per_sec: if seconds > 0.0 { r.evals as f64 / seconds } else { 0.0 },
        peak_arena_bytes: r.peak_arena_bytes,
        best_cost_ms: r.best_cost_ms,
        cache_hits: cache.hits,
        cache_misses: cache.misses,
        cache_evictions: cache.evictions,
    }
}

/// Measure the search hot path (before / after / delta) on the acceptance
/// workload. Always uses the *full* `transformer_base` spec (the record
/// is about engine throughput, not CI speed); `opts.scale` only sizes the
/// budget.
pub fn search_hot_path_record(opts: &BenchOptions) -> HotPathRecord {
    let cluster = Cluster::cluster_a();
    let device = BenchOptions::device_for(&cluster);
    let graph = models::build(&ModelSpec::transformer_base(), cluster.num_devices());
    let profile = profiler::profile(&graph, &device, &cluster, 2, opts.seed);
    let unchanged_limit = match opts.scale {
        Scale::Full => 400,
        Scale::Fast => 150,
    };
    let base = SearchConfig { unchanged_limit, seed: opts.seed, ..Default::default() };
    let before_cfg = SearchConfig {
        eval_threads: 1,
        delta_candidates: false,
        reuse_workspaces: false,
        incremental_candidates: false,
        cost_table: false,
        delta_sim: false,
        ..base.clone()
    };
    // PR-1 engine: everything allocation-free, but every candidate fully
    // simulated with per-event dyn-dispatched costs.
    let after_cfg = SearchConfig { cost_table: false, delta_sim: false, ..base.clone() };
    // Fresh estimator (cold prediction memo) and fresh graph (cold CSR
    // cache) per arm — sharing them would hand a later run a pre-warmed
    // cache and bias the throughput ratios by run order.
    let before = {
        let est = CostEstimator::oracle(&profile, &device);
        timed_search(&graph.clone(), &est, &before_cfg)
    };
    let after = {
        let est = CostEstimator::oracle(&profile, &device);
        timed_search(&graph.clone(), &est, &after_cfg)
    };
    let delta = {
        let est = CostEstimator::oracle(&profile, &device);
        timed_search(&graph.clone(), &est, &base)
    };
    HotPathRecord {
        model: "transformer_base",
        workers: cluster.num_devices(),
        unchanged_limit,
        seed: opts.seed,
        before,
        after,
        delta,
    }
}

// ---------------------------------------------------------------------------
// Chunked-collective A/B record (the chunk_bench arm of BENCH_search.json).
// ---------------------------------------------------------------------------

/// One model's fusion-only vs joint fusion+chunking search outcome.
#[derive(Debug, Clone)]
pub struct ChunkArmStats {
    pub model: String,
    pub workers: usize,
    pub initial_ms: f64,
    /// Best simulated iteration time under the paper's fusion-only
    /// vocabulary.
    pub unchunked_ms: f64,
    /// Best with the chunking method added. The joint search is
    /// warm-started from the fusion-only winner's mutation path, so it
    /// can never end worse than `unchunked_ms` — any gap is overlap the
    /// chunk vocabulary bought.
    pub chunked_ms: f64,
    pub chunked_evals: u64,
    /// Live AllReduces carrying a chunk schedule in the winning plan.
    pub chunked_ars: usize,
}

impl ChunkArmStats {
    pub fn speedup(&self) -> f64 {
        if self.chunked_ms == 0.0 { 1.0 } else { self.unchunked_ms / self.chunked_ms }
    }
}

/// The `chunk_bench` arm: does adding the chunking method to the search
/// vocabulary (DESIGN.md §13) find strictly faster plans than the best
/// fusion-only strategy on the model zoo?
#[derive(Debug, Clone)]
pub struct ChunkBenchRecord {
    pub seed: u64,
    pub unchanged_limit: usize,
    pub max_chunks: u32,
    pub models: Vec<ChunkArmStats>,
}

impl ChunkBenchRecord {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("bench", Json::Str("chunk_bench".into())),
            ("seed", Json::Num(self.seed as f64)),
            ("unchanged_limit", Json::Num(self.unchanged_limit as f64)),
            ("max_chunks", Json::Num(self.max_chunks as f64)),
            ("measured", Json::Bool(true)),
            (
                "models",
                Json::Arr(
                    self.models
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("model", Json::Str(m.model.clone())),
                                ("workers", Json::Num(m.workers as f64)),
                                ("initial_ms", Json::Num(m.initial_ms)),
                                ("unchunked_ms", Json::Num(m.unchunked_ms)),
                                ("chunked_ms", Json::Num(m.chunked_ms)),
                                ("speedup", Json::Num(m.speedup())),
                                ("chunked_evals", Json::Num(m.chunked_evals as f64)),
                                ("chunked_ars", Json::Num(m.chunked_ars as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Measure the chunking A/B on two comm-heavy zoo entries. The chunked
/// arm runs [`crate::search::backtracking_search_seeded`] warm-started
/// from the fusion-only winner's recorded path, so its result is a
/// guaranteed-no-worse refinement of the same strategy — the comparison
/// isolates what the chunk vocabulary adds rather than trajectory noise.
pub fn chunk_bench_record(opts: &BenchOptions) -> ChunkBenchRecord {
    use crate::search::backtracking_search_seeded;
    let cluster = Cluster::cluster_a();
    let device = BenchOptions::device_for(&cluster);
    let unchanged_limit = match opts.scale {
        Scale::Full => 400,
        Scale::Fast => 100,
    };
    let max_chunks = 8u32;
    let mut arms = Vec::new();
    for kind in [ModelKind::Transformer, ModelKind::Rnnlm] {
        let graph = models::build(&opts.spec(kind), cluster.num_devices());
        let profile = profiler::profile(&graph, &device, &cluster, 2, opts.seed ^ kind as u64);
        let est = CostEstimator::analytical(&profile, &cluster);
        let base = SearchConfig {
            unchanged_limit,
            seed: opts.seed,
            track_best_path: true,
            ..Default::default()
        };
        let unchunked = backtracking_search(&graph, &est, &base);
        let chunked_cfg = SearchConfig {
            methods: MethodSet::all_with_chunking(),
            max_chunks,
            ..base.clone()
        };
        let chunked = backtracking_search_seeded(
            &graph,
            &est,
            &chunked_cfg,
            &[unchunked.best_path.clone()],
        );
        arms.push(ChunkArmStats {
            model: kind.name().to_string(),
            workers: cluster.num_devices(),
            initial_ms: unchunked.initial_cost_ms,
            unchunked_ms: unchunked.best_cost_ms,
            chunked_ms: chunked.best_cost_ms,
            chunked_evals: chunked.evals,
            chunked_ars: chunked
                .best
                .live()
                .filter(|n| n.chunk_count() >= 2)
                .count(),
        });
    }
    ChunkBenchRecord { seed: opts.seed, unchanged_limit, max_chunks, models: arms }
}

// ---------------------------------------------------------------------------
// Gradient-sharding A/B record (the shard_bench arm of BENCH_search.json).
// ---------------------------------------------------------------------------

/// One model's DDP (fusion-only) vs joint fusion+sharding search outcome.
#[derive(Debug, Clone)]
pub struct ShardArmStats {
    pub model: String,
    pub workers: usize,
    pub initial_ms: f64,
    /// Best simulated iteration time with whole-tensor AllReduces (DDP
    /// semantics, the paper's fusion-only vocabulary).
    pub ddp_ms: f64,
    /// Best with the gradient-sharding method added (DESIGN.md §16). The
    /// joint search is warm-started from the DDP winner's mutation path,
    /// so it can never end worse than `ddp_ms` — any gap is what
    /// reduce-scatter/all-gather scheduling bought (sharded optimizer
    /// compute plus the all-gather hidden behind the next forward pass).
    pub sharded_ms: f64,
    pub sharded_evals: u64,
    /// Live AllReduces running reduce-scatter/all-gather in the winner.
    pub sharded_ars: usize,
}

impl ShardArmStats {
    pub fn speedup(&self) -> f64 {
        if self.sharded_ms == 0.0 { 1.0 } else { self.ddp_ms / self.sharded_ms }
    }
}

/// The `shard_bench` arm: does adding ZeRO/FSDP-style gradient sharding
/// to the search vocabulary find strictly faster plans than the best
/// DDP (fusion-only) strategy on the model zoo?
#[derive(Debug, Clone)]
pub struct ShardBenchRecord {
    pub seed: u64,
    pub unchanged_limit: usize,
    pub models: Vec<ShardArmStats>,
}

impl ShardBenchRecord {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("bench", Json::Str("shard_bench".into())),
            ("seed", Json::Num(self.seed as f64)),
            ("unchanged_limit", Json::Num(self.unchanged_limit as f64)),
            ("measured", Json::Bool(true)),
            (
                "models",
                Json::Arr(
                    self.models
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("model", Json::Str(m.model.clone())),
                                ("workers", Json::Num(m.workers as f64)),
                                ("initial_ms", Json::Num(m.initial_ms)),
                                ("ddp_ms", Json::Num(m.ddp_ms)),
                                ("sharded_ms", Json::Num(m.sharded_ms)),
                                ("speedup", Json::Num(m.speedup())),
                                ("sharded_evals", Json::Num(m.sharded_evals as f64)),
                                ("sharded_ars", Json::Num(m.sharded_ars as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Measure the sharding A/B on the same two comm-heavy zoo entries as
/// `chunk_bench`. The sharded arm runs
/// [`crate::search::backtracking_search_seeded`] warm-started from the
/// DDP winner's recorded path, so its result is a guaranteed-no-worse
/// refinement of the same strategy — the comparison isolates what the
/// sharding vocabulary adds rather than trajectory noise. (Unlike
/// chunking, a sharded collective is *not* clamped never-worse inside
/// the simulator — it pays the per-collective overhead twice — so the
/// warm start is what makes `sharded_ms <= ddp_ms` a structural
/// guarantee rather than a modeling one.)
pub fn shard_bench_record(opts: &BenchOptions) -> ShardBenchRecord {
    use crate::search::backtracking_search_seeded;
    let cluster = Cluster::cluster_a();
    let device = BenchOptions::device_for(&cluster);
    let unchanged_limit = match opts.scale {
        Scale::Full => 400,
        Scale::Fast => 100,
    };
    let mut arms = Vec::new();
    for kind in [ModelKind::Transformer, ModelKind::Rnnlm] {
        let graph = models::build(&opts.spec(kind), cluster.num_devices());
        let profile = profiler::profile(&graph, &device, &cluster, 2, opts.seed ^ kind as u64);
        let est = CostEstimator::analytical(&profile, &cluster);
        let base = SearchConfig {
            unchanged_limit,
            seed: opts.seed,
            track_best_path: true,
            ..Default::default()
        };
        let ddp = backtracking_search(&graph, &est, &base);
        let sharded_cfg = SearchConfig {
            methods: MethodSet::all_with_sharding(),
            ..base.clone()
        };
        let sharded = backtracking_search_seeded(
            &graph,
            &est,
            &sharded_cfg,
            &[ddp.best_path.clone()],
        );
        arms.push(ShardArmStats {
            model: kind.name().to_string(),
            workers: cluster.num_devices(),
            initial_ms: ddp.initial_cost_ms,
            ddp_ms: ddp.best_cost_ms,
            sharded_ms: sharded.best_cost_ms,
            sharded_evals: sharded.evals,
            sharded_ars: sharded
                .best
                .live()
                .filter(|n| n.is_sharded_collective())
                .count(),
        });
    }
    ShardBenchRecord { seed: opts.seed, unchanged_limit, models: arms }
}

/// Repository root (the parent of the `rust/` crate), resolved at compile
/// time so the record lands in the same place regardless of cwd.
pub fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."))
}

/// Upsert one record into the JSONL perf-record file: the existing line
/// with the same `"bench"` tag (if any) is replaced, every other arm's
/// line is preserved in order. The file holds one JSON object per line,
/// one line per bench arm (`search_hot_path`, `chunk_bench`, ...), so
/// regenerating one arm never clobbers another's record.
fn upsert_bench_record(
    path: &std::path::Path,
    record: &crate::util::json::Json,
) -> std::io::Result<()> {
    use crate::util::json::Json;
    let tag = record.get("bench").as_str().unwrap_or_default().to_string();
    let mut lines: Vec<String> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let keep = Json::parse(line)
                .ok()
                .map_or(true, |j| j.get("bench").as_str() != Some(tag.as_str()));
            if keep {
                lines.push(line.to_string());
            }
        }
    }
    lines.push(record.to_string());
    std::fs::write(path, lines.join("\n") + "\n")
}

/// Run the A/B measurement and upsert the `search_hot_path` line of
/// `BENCH_search.json` at the repo root. Returns the record and the path
/// written.
pub fn write_search_perf_record(
    opts: &BenchOptions,
) -> std::io::Result<(HotPathRecord, std::path::PathBuf)> {
    let record = search_hot_path_record(opts);
    let path = repo_root().join("BENCH_search.json");
    upsert_bench_record(&path, &record.to_json())?;
    Ok((record, path))
}

/// Run the chunking A/B and upsert the `chunk_bench` line of
/// `BENCH_search.json` at the repo root.
pub fn write_chunk_bench_record(
    opts: &BenchOptions,
) -> std::io::Result<(ChunkBenchRecord, std::path::PathBuf)> {
    let record = chunk_bench_record(opts);
    let path = repo_root().join("BENCH_search.json");
    upsert_bench_record(&path, &record.to_json())?;
    Ok((record, path))
}

/// Run the sharding A/B and upsert the `shard_bench` line of
/// `BENCH_search.json` at the repo root.
pub fn write_shard_bench_record(
    opts: &BenchOptions,
) -> std::io::Result<(ShardBenchRecord, std::path::PathBuf)> {
    let record = shard_bench_record(opts);
    let path = repo_root().join("BENCH_search.json");
    upsert_bench_record(&path, &record.to_json())?;
    Ok((record, path))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_all_schemes_ordering_and_sanity() {
        let opts = BenchOptions { scale: Scale::Fast, ..Default::default() };
        let cluster = Cluster::cluster_a();
        let p = prepare(&opts, ModelKind::Rnnlm, &cluster);
        let (schemes, result) = run_all_schemes(&p, &opts);
        assert_eq!(schemes.len(), 7);
        assert_eq!(schemes[0].scheme, "JAX_no_fusion");
        assert_eq!(schemes[5].scheme, "DisCo");
        assert_eq!(schemes[6].scheme, "FO");
        let disco = schemes[5].sim.makespan_ms;
        let fo = schemes[6].sim.makespan_ms;
        let best_baseline = schemes[..5]
            .iter()
            .map(|s| s.sim.makespan_ms)
            .fold(f64::INFINITY, f64::min);
        assert!(disco <= best_baseline * 1.05, "disco {disco} vs baseline {best_baseline}");
        assert!(disco >= fo * 0.999, "disco {disco} below FO {fo}");
        assert!(result.best.validate().is_ok());
    }

    #[test]
    fn chunk_bench_chunked_never_worse() {
        let opts = BenchOptions { scale: Scale::Fast, ..Default::default() };
        let rec = chunk_bench_record(&opts);
        assert_eq!(rec.models.len(), 2);
        for m in &rec.models {
            // Warm-started from the fusion-only winner, so the chunked
            // arm is a guaranteed-no-worse refinement.
            assert!(
                m.chunked_ms <= m.unchunked_ms + 1e-9,
                "{}: chunked {} worse than unchunked {}",
                m.model,
                m.chunked_ms,
                m.unchunked_ms
            );
            assert!(m.unchunked_ms <= m.initial_ms + 1e-9);
            assert!(m.chunked_evals > 0);
        }
        let j = rec.to_json();
        assert_eq!(j.get("bench").as_str(), Some("chunk_bench"));
        assert_eq!(j.get("models").as_arr().map(|a| a.len()), Some(2));
    }

    #[test]
    fn shard_bench_sharded_never_worse() {
        let opts = BenchOptions { scale: Scale::Fast, ..Default::default() };
        let rec = shard_bench_record(&opts);
        assert_eq!(rec.models.len(), 2);
        for m in &rec.models {
            // Warm-started from the DDP winner, so the sharded arm is a
            // guaranteed-no-worse refinement (the simulator itself does
            // NOT clamp sharding — this bound comes from the warm start).
            assert!(
                m.sharded_ms <= m.ddp_ms + 1e-9,
                "{}: sharded {} worse than DDP {}",
                m.model,
                m.sharded_ms,
                m.ddp_ms
            );
            assert!(m.ddp_ms <= m.initial_ms + 1e-9);
            assert!(m.sharded_evals > 0);
        }
        let j = rec.to_json();
        assert_eq!(j.get("bench").as_str(), Some("shard_bench"));
        assert_eq!(j.get("models").as_arr().map(|a| a.len()), Some(2));
    }

    #[test]
    fn upsert_preserves_other_bench_lines() {
        use crate::util::json::Json;
        let dir = std::env::temp_dir().join(format!("disco_upsert_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_search.json");
        let hot = Json::obj(vec![
            ("bench", Json::Str("search_hot_path".into())),
            ("measured", Json::Bool(false)),
        ]);
        let chunk1 = Json::obj(vec![
            ("bench", Json::Str("chunk_bench".into())),
            ("measured", Json::Bool(false)),
        ]);
        upsert_bench_record(&path, &hot).unwrap();
        upsert_bench_record(&path, &chunk1).unwrap();
        // Re-upserting one arm replaces its line and keeps the other.
        let chunk2 = Json::obj(vec![
            ("bench", Json::Str("chunk_bench".into())),
            ("measured", Json::Bool(true)),
        ]);
        upsert_bench_record(&path, &chunk2).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
        assert_eq!(lines.len(), 2);
        let tags: Vec<_> = lines
            .iter()
            .map(|l| Json::parse(l).unwrap().get("bench").as_str().unwrap().to_string())
            .collect();
        assert!(tags.contains(&"search_hot_path".to_string()));
        assert!(tags.contains(&"chunk_bench".to_string()));
        let chunk_line = lines
            .iter()
            .find(|l| l.contains("chunk_bench"))
            .unwrap();
        assert_eq!(Json::parse(chunk_line).unwrap().get("measured").as_bool(), Some(true));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn forward_only_strips_backward() {
        let g = models::build(&ModelSpec { kind: ModelKind::Rnnlm, batch: 8, depth_scale: 0.2 }, 4);
        let f = g.forward_only();
        assert!(f.validate().is_ok());
        assert!(f.allreduces().is_empty());
        assert!(f.live_count() < g.live_count());
    }
}
