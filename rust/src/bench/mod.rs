//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation section (§6) as markdown tables (see DESIGN.md §5 for the
//! experiment index). The `disco bench <exp>` CLI drives these.
//!
//! Scale: `Scale::Full` uses the published model architectures and paper
//! hyper-parameters (α = 1.05, β = 10, unchanged limit 1000); CI and quick
//! runs use `Scale::Fast` (quarter-depth models, smaller search budget).
//! Absolute milliseconds live on our simulated testbed, not the authors'
//! GPUs — the reproduction target is the *shape*: who wins, by roughly
//! what factor, where the crossovers fall (see EXPERIMENTS.md).

pub mod experiments;
pub mod gnn_pipeline;

use crate::baselines;
use crate::device::DeviceModel;
use crate::estimator::CostEstimator;
use crate::graph::TrainingGraph;
use crate::models::{self, ModelKind, ModelSpec};
use crate::network::Cluster;
use crate::profiler::{self, ProfileData};
use crate::search::{backtracking_search, MethodSet, SearchConfig, SearchResult};
use crate::sim::{fo_bound, simulate, CostSource, SimOptions, SimResult};

/// Benchmark scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// Published architectures, paper search budget.
    Full,
    /// Quarter-depth models, reduced search budget (CI-friendly).
    Fast,
}

/// Which fused-op estimator backs the search cost model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EstimatorKind {
    /// White-box heuristic from profiled quantities (no GNN).
    Analytical,
    /// The GNN Fused-Op Estimator via PJRT (paper §4.3). Trained on
    /// profiler-generated samples before use.
    Gnn,
    /// Device-model ground truth (upper bound; not available to a real
    /// system — ablations only).
    Oracle,
}

impl EstimatorKind {
    pub fn parse(s: &str) -> Option<EstimatorKind> {
        match s {
            "analytical" => Some(EstimatorKind::Analytical),
            "gnn" => Some(EstimatorKind::Gnn),
            "oracle" => Some(EstimatorKind::Oracle),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EstimatorKind::Analytical => "analytical",
            EstimatorKind::Gnn => "gnn",
            EstimatorKind::Oracle => "oracle",
        }
    }
}

/// Everything a benchmark run needs.
#[derive(Debug, Clone)]
pub struct BenchOptions {
    pub scale: Scale,
    pub estimator: EstimatorKind,
    pub seed: u64,
    pub alpha: f64,
    pub beta: usize,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions {
            scale: Scale::Fast,
            estimator: EstimatorKind::Analytical,
            seed: 0xD15C0,
            alpha: 1.05,
            beta: 10,
        }
    }
}

impl BenchOptions {
    pub fn spec(&self, kind: ModelKind) -> ModelSpec {
        let mut spec = match kind {
            ModelKind::Vgg19 => ModelSpec::vgg19(),
            ModelKind::ResNet50 => ModelSpec::resnet50(),
            ModelKind::Transformer => ModelSpec::transformer_base(),
            ModelKind::Rnnlm => ModelSpec::rnnlm(),
            ModelKind::Bert => ModelSpec::bert_base(),
            ModelKind::Reformer => ModelSpec::reformer(),
        };
        if self.scale == Scale::Fast {
            spec.depth_scale = 0.25;
            spec.batch = (spec.batch / 2).max(4);
        }
        spec
    }

    pub fn search_config(&self) -> SearchConfig {
        SearchConfig {
            alpha: self.alpha,
            beta: self.beta,
            unchanged_limit: match self.scale {
                Scale::Full => 1000,
                Scale::Fast => 150,
            },
            max_queue: 256,
            max_seconds: 0.0,
            methods: MethodSet::all(),
            sim: SimOptions::default(),
            seed: self.seed,
        }
    }

    /// Device model for a cluster (A → 1080Ti, B → T4).
    pub fn device_for(cluster: &Cluster) -> DeviceModel {
        if cluster.name == "B" {
            DeviceModel::tesla_t4()
        } else {
            DeviceModel::gtx1080ti()
        }
    }
}

/// Build + profile one model on a cluster.
pub struct Prepared {
    pub kind: ModelKind,
    pub graph: TrainingGraph,
    pub device: DeviceModel,
    pub cluster: Cluster,
    pub profile: ProfileData,
}

pub fn prepare(opts: &BenchOptions, kind: ModelKind, cluster: &Cluster) -> Prepared {
    let device = BenchOptions::device_for(cluster);
    let graph = models::build(&opts.spec(kind), cluster.num_devices());
    let profile = profiler::profile(&graph, &device, cluster, 3, opts.seed ^ kind as u64);
    Prepared { kind, graph, device, cluster: cluster.clone(), profile }
}

impl Prepared {
    /// Estimator of the requested kind. GNN needs pretrained params —
    /// callers that want the GNN path use [`gnn_pipeline`] to obtain a
    /// predictor and construct the estimator themselves; here Gnn falls
    /// back to Oracle so table harnesses remain runnable without
    /// artifacts.
    pub fn estimator(&self, kind: EstimatorKind) -> CostEstimator<'_> {
        match kind {
            EstimatorKind::Analytical => CostEstimator::analytical(&self.profile, &self.cluster),
            EstimatorKind::Oracle | EstimatorKind::Gnn => {
                CostEstimator::oracle(&self.profile, &self.device)
            }
        }
    }

    pub fn cost(&self, graph: &TrainingGraph, est: &CostEstimator<'_>) -> SimResult {
        est.prepare(graph);
        simulate(graph, est, SimOptions::default())
    }
}

/// One scheme's outcome on one (model, cluster).
#[derive(Debug, Clone)]
pub struct SchemeResult {
    pub scheme: &'static str,
    pub sim: SimResult,
}

/// Run every baseline scheme + DisCo + the FO bound. Returns results in
/// presentation order (paper Fig. 6 legend order).
pub fn run_all_schemes(p: &Prepared, opts: &BenchOptions) -> (Vec<SchemeResult>, SearchResult) {
    let est = p.estimator(opts.estimator);
    let mut out = Vec::new();
    let schemes: Vec<(&'static str, TrainingGraph)> = vec![
        ("JAX_no_fusion", baselines::no_fusion(&p.graph)),
        ("JAX_op_fusion", baselines::xla_op_fusion(&p.graph)),
        (
            "JAX_AllReduce_fusion",
            baselines::ar_threshold_fusion(&p.graph, baselines::XLA_AR_THRESHOLD),
        ),
        ("JAX_default", baselines::jax_default(&p.graph)),
        ("PyTorch_DDP", baselines::pytorch_ddp(&p.graph)),
    ];
    for (name, g) in &schemes {
        out.push(SchemeResult { scheme: name, sim: p.cost(g, &est) });
    }
    let result = backtracking_search(&p.graph, &est, &opts.search_config());
    out.push(SchemeResult { scheme: "DisCo", sim: p.cost(&result.best, &est) });
    // FO lower bound, per the paper: full overlap of the best module's
    // computation and communication.
    let fo = fo_bound(&result.best, &est);
    out.push(SchemeResult {
        scheme: "FO",
        sim: SimResult {
            makespan_ms: fo,
            comp_busy_ms: 0.0,
            comm_busy_ms: 0.0,
            kernels: 0,
            allreduces: 0,
            peak_bytes: 0.0,
        },
    });
    (out, result)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_all_schemes_ordering_and_sanity() {
        let opts = BenchOptions { scale: Scale::Fast, ..Default::default() };
        let cluster = Cluster::cluster_a();
        let p = prepare(&opts, ModelKind::Rnnlm, &cluster);
        let (schemes, result) = run_all_schemes(&p, &opts);
        assert_eq!(schemes.len(), 7);
        assert_eq!(schemes[0].scheme, "JAX_no_fusion");
        assert_eq!(schemes[5].scheme, "DisCo");
        assert_eq!(schemes[6].scheme, "FO");
        let disco = schemes[5].sim.makespan_ms;
        let fo = schemes[6].sim.makespan_ms;
        let best_baseline = schemes[..5]
            .iter()
            .map(|s| s.sim.makespan_ms)
            .fold(f64::INFINITY, f64::min);
        assert!(disco <= best_baseline * 1.05, "disco {disco} vs baseline {best_baseline}");
        assert!(disco >= fo * 0.999, "disco {disco} below FO {fo}");
        assert!(result.best.validate().is_ok());
    }

    #[test]
    fn forward_only_strips_backward() {
        let g = models::build(&ModelSpec { kind: ModelKind::Rnnlm, batch: 8, depth_scale: 0.2 }, 4);
        let f = g.forward_only();
        assert!(f.validate().is_ok());
        assert!(f.allreduces().is_empty());
        assert!(f.live_count() < g.live_count());
    }
}
