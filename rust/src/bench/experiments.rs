//! One function per paper table/figure (DESIGN.md §5 experiment index).
//! Each returns a markdown section suitable for EXPERIMENTS.md.

use super::{prepare, run_all_schemes, BenchOptions, EstimatorKind, Scale};
use crate::baselines;
use crate::models::{self, ModelKind};
use crate::network::Cluster;
use crate::search::{backtracking_search, MethodSet};
use crate::sim::hifi::{execute_real, HifiOptions};
use crate::sim::{simulate, CostSource, SimOptions};
use crate::util::table::{fmt_ms, fmt_pct, Table};
use anyhow::Result;
use std::path::Path;

const FIG7_MODELS: [ModelKind; 4] =
    [ModelKind::Vgg19, ModelKind::ResNet50, ModelKind::Transformer, ModelKind::Rnnlm];

/// Fig. 6 (per-iteration time, both clusters) + Table 1 (speed-ups).
pub fn fig6_table1(opts: &BenchOptions) -> String {
    let mut out = String::new();
    let mut table1 = Table::new(
        "Table 1 — speed-up of DisCo and FO vs best baseline",
        &["model", "cluster A DisCo", "cluster A FO", "cluster B DisCo", "cluster B FO"],
    );
    let mut speedups: Vec<Vec<String>> =
        ModelKind::ALL.iter().map(|m| vec![m.name().to_string()]).collect();

    for cluster in [Cluster::cluster_a(), Cluster::cluster_b()] {
        let mut fig6 = Table::new(
            &format!(
                "Fig. 6 — per-iteration training time (ms), cluster {} ({} devices)",
                cluster.name,
                cluster.num_devices()
            ),
            &["model", "no_fusion", "op_fusion", "AR_fusion", "JAX_default", "DDP", "DisCo", "FO"],
        );
        for (mi, kind) in ModelKind::ALL.iter().enumerate() {
            let p = prepare(opts, *kind, &cluster);
            let (schemes, _) = run_all_schemes(&p, opts);
            let mut row = vec![kind.name().to_string()];
            for s in &schemes {
                row.push(fmt_ms(s.sim.makespan_ms));
            }
            fig6.row(row);
            // Table 1 numbers.
            let t_min = schemes[..5]
                .iter()
                .map(|s| s.sim.makespan_ms)
                .fold(f64::INFINITY, f64::min);
            let t_disco = schemes[5].sim.makespan_ms;
            let t_fo = schemes[6].sim.makespan_ms;
            speedups[mi].push(fmt_pct((t_min - t_disco) / t_disco));
            speedups[mi].push(fmt_pct((t_min - t_fo) / t_fo));
        }
        out.push_str(&fig6.to_markdown());
        out.push('\n');
    }
    for row in speedups {
        table1.row(row);
    }
    out.push_str(&table1.to_markdown());
    out
}

/// Fig. 7 — computation/communication/per-iteration breakdown + overlap
/// ratio, 4 models on cluster A.
pub fn fig7(opts: &BenchOptions) -> String {
    let cluster = Cluster::cluster_a();
    let mut out = String::new();
    for kind in FIG7_MODELS {
        let p = prepare(opts, kind, &cluster);
        let (schemes, _) = run_all_schemes(&p, opts);
        let mut t = Table::new(
            &format!("Fig. 7 — time breakdown (ms), {} on cluster A", kind.name()),
            &["scheme", "per-iteration", "computation", "communication", "overlap ratio"],
        );
        for s in &schemes {
            if s.scheme == "FO" {
                continue;
            }
            t.row(vec![
                s.scheme.to_string(),
                fmt_ms(s.sim.makespan_ms),
                fmt_ms(s.sim.comp_busy_ms),
                fmt_ms(s.sim.comm_busy_ms),
                format!("{:.2}", s.sim.overlap_ratio()),
            ]);
        }
        out.push_str(&t.to_markdown());
        out.push('\n');
    }
    out
}

/// Fig. 8 — single-device inference time vs rule-based compilers + TASO.
pub fn fig8(opts: &BenchOptions) -> String {
    let cluster = Cluster::single_device();
    let device = crate::device::DeviceModel::gtx1080ti();
    let sim_opts = SimOptions { ignore_comm: true, ..Default::default() };
    let mut t = Table::new(
        "Fig. 8 — single-device inference time (ms, GTX-1080-Ti-like)",
        &["model", "JAX_default", "nGraph", "TVM", "TASO-like", "DisCo"],
    );
    for kind in ModelKind::ALL {
        let full = models::build(&opts.spec(kind), 1);
        let g = full.forward_only();
        let prof = crate::profiler::profile(&g, &device, &cluster, 3, opts.seed ^ kind as u64);
        let est = crate::estimator::CostEstimator::oracle(&prof, &device);
        let cost = |graph: &crate::graph::TrainingGraph| {
            est.prepare(graph);
            simulate(graph, &est, sim_opts).makespan_ms
        };
        let taso_steps = if opts.scale == Scale::Full { 400 } else { 120 };
        let mut cfg = opts.search_config();
        cfg.methods = MethodSet { nondup_fusion: true, dup_fusion: true, ..MethodSet::none() };
        cfg.sim = sim_opts;
        let disco = backtracking_search(&g, &est, &cfg);
        t.row(vec![
            kind.name().to_string(),
            fmt_ms(cost(&baselines::xla_op_fusion(&g))),
            fmt_ms(cost(&baselines::ngraph_fusion(&g))),
            fmt_ms(cost(&baselines::tvm_rule_fusion(&g))),
            fmt_ms(cost(&baselines::taso_like(&g, &est, sim_opts, taso_steps, opts.seed))),
            fmt_ms(disco.best_cost_ms),
        ]);
    }
    t.to_markdown()
}

/// Fig. 9 — PDF/CDF of GNN Fused-Op-Estimator prediction error on unseen
/// fused ops. Requires AOT artifacts.
pub fn fig9(opts: &BenchOptions, artifacts: &Path) -> Result<String> {
    let (train_n, test_n, epochs) = match opts.scale {
        Scale::Full => (1000, 340, 40),
        Scale::Fast => (300, 80, 40),
    };
    let report = super::gnn_pipeline::train_and_eval(opts, artifacts, train_n, test_n, epochs)?;
    super::gnn_pipeline::save_params(artifacts, &report.params)?;
    let mut out = String::new();
    out.push_str(&format!(
        "### Fig. 9 — GNN Fused-Op Estimator prediction error\n\n\
         trained on {} samples ({} epochs, log-MSE {:.4} → {:.4}), evaluated on {} unseen fused ops\n\n\
         - mean relative error: {}\n- p90 relative error: {}\n\
         - within 14% of real time: {} (paper: >90%)\n- within 5%: {}\n\n",
        report.train_samples,
        report.epochs,
        report.first_loss,
        report.last_loss,
        report.test_samples,
        fmt_pct(report.mean_error()),
        fmt_pct(report.p90_error()),
        fmt_pct(report.frac_within(0.14)),
        fmt_pct(report.frac_within(0.05)),
    ));
    let mut t = Table::new("error distribution (PDF/CDF)", &["error ≤", "PDF", "CDF"]);
    let pdf = report.hist.pdf();
    let cdf = report.hist.cdf();
    for i in 0..pdf.len() {
        if i % 2 == 1 {
            continue; // print every other bin: 30 bins → 15 rows
        }
        t.row(vec![
            format!("{:.2}", report.hist.edge(i)),
            format!("{:.3}", pdf[i]),
            format!("{:.3}", cdf[i]),
        ]);
    }
    out.push_str(&t.to_markdown());
    Ok(out)
}

/// Table 2 — simulator estimate vs "real" (hi-fi) execution time.
pub fn table2(opts: &BenchOptions) -> String {
    let cluster = Cluster::cluster_a();
    let mut t = Table::new(
        "Table 2 — estimation error of the simulator (cluster A)",
        &["model", "real execution (ms)", "simulation (ms)", "error"],
    );
    for kind in ModelKind::ALL {
        let p = prepare(opts, kind, &cluster);
        let est = p.estimator(opts.estimator);
        let cfg = opts.search_config();
        let result = backtracking_search(&p.graph, &est, &cfg);
        let sim_ms = result.best_cost_ms;
        let real = execute_real(
            &result.best,
            &p.device,
            &p.cluster,
            &HifiOptions { iterations: 10, seed: opts.seed ^ 0xAB, ..Default::default() },
        );
        let err = (sim_ms - real.makespan_ms).abs() / real.makespan_ms;
        t.row(vec![
            kind.name().to_string(),
            fmt_ms(real.makespan_ms),
            fmt_ms(sim_ms),
            fmt_pct(err),
        ]);
    }
    t.to_markdown()
}

/// Fig. 10 — contribution of each optimization method (ablation).
pub fn fig10(opts: &BenchOptions) -> String {
    let cluster = Cluster::cluster_a();
    let variants: [(&str, MethodSet); 4] = [
        ("none (no fusion)", MethodSet::none()),
        ("+non-dup", MethodSet { nondup_fusion: true, ..MethodSet::none() }),
        ("+non-dup+dup", MethodSet { nondup_fusion: true, dup_fusion: true, ..MethodSet::none() }),
        ("+all (DisCo)", MethodSet::all()),
    ];
    let mut t = Table::new(
        "Fig. 10 — per-iteration time (ms) with optimization methods added incrementally (cluster A)",
        &["model", "none (no fusion)", "+non-dup", "+non-dup+dup", "+all (DisCo)"],
    );
    for kind in ModelKind::ALL {
        let p = prepare(opts, kind, &cluster);
        let est = p.estimator(opts.estimator);
        let mut row = vec![kind.name().to_string()];
        for (_, methods) in &variants {
            let mut cfg = opts.search_config();
            cfg.methods = *methods;
            let r = backtracking_search(&p.graph, &est, &cfg);
            row.push(fmt_ms(r.best_cost_ms));
        }
        t.row(row);
    }
    t.to_markdown()
}

/// Table 3 — α sweep: strategy quality vs search time.
pub fn table3(opts: &BenchOptions) -> String {
    sweep_table(
        opts,
        "Table 3 — per-iteration time (ms) / search time (s) for α",
        &[("α=1", 1.0, None), ("α=1.05", 1.05, None), ("α=1.1", 1.1, None)],
    )
}

/// Table 4 — β sweep: strategy quality vs search time.
pub fn table4(opts: &BenchOptions) -> String {
    sweep_table(
        opts,
        "Table 4 — per-iteration time (ms) / search time (s) for β",
        &[("β=1", -1.0, Some(1)), ("β=5", -1.0, Some(5)), ("β=10", -1.0, Some(10)), ("β=30", -1.0, Some(30))],
    )
}

fn sweep_table(
    opts: &BenchOptions,
    title: &str,
    variants: &[(&str, f64, Option<usize>)],
) -> String {
    let cluster = Cluster::cluster_a();
    let mut header = vec!["model"];
    header.extend(variants.iter().map(|(n, _, _)| *n));
    let mut t = Table::new(title, &header);
    for kind in ModelKind::ALL {
        let p = prepare(opts, kind, &cluster);
        let est = p.estimator(opts.estimator);
        let mut row = vec![kind.name().to_string()];
        for (_, alpha, beta) in variants {
            let mut cfg = opts.search_config();
            if *alpha > 0.0 {
                cfg.alpha = *alpha;
            }
            if let Some(b) = beta {
                cfg.beta = *b;
            }
            let r = backtracking_search(&p.graph, &est, &cfg);
            row.push(format!(
                "{}/{:.1}s",
                fmt_ms(r.best_cost_ms),
                r.elapsed.as_secs_f64()
            ));
        }
        t.row(row);
    }
    t.to_markdown()
}

/// Designed-in extra ablation (DESIGN.md §5): how much estimator quality
/// matters — search driven by analytical vs GNN vs oracle backends, with
/// the *resulting strategy* always evaluated under the oracle.
pub fn ablation_estimator(opts: &BenchOptions, artifacts: Option<&Path>) -> Result<String> {
    let cluster = Cluster::cluster_a();
    let mut t = Table::new(
        "Ablation — fused-op estimator backend (strategies re-scored by oracle, ms)",
        &["model", "analytical", "gnn", "oracle"],
    );
    // Optional trained GNN predictor shared across models. The default
    // interpreter backend bootstraps an empty artifact dir; only the PJRT
    // backend (offline stub) leaves `rt` as None and skips the GNN arm —
    // any other failure (corrupt manifest, unreadable params) is reported
    // rather than silently dropping the column.
    let rt = artifacts.and_then(|dir| match crate::runtime::Runtime::new(dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("ablation: GNN arm skipped: {e:#}");
            None
        }
    });
    for kind in [ModelKind::Rnnlm, ModelKind::Transformer] {
        let p = prepare(opts, kind, &cluster);
        let oracle = p.estimator(EstimatorKind::Oracle);
        let mut row = vec![kind.name().to_string()];
        for backend in ["analytical", "gnn", "oracle"] {
            let cfg = opts.search_config();
            let best = match backend {
                "analytical" => {
                    let est = p.estimator(EstimatorKind::Analytical);
                    backtracking_search(&p.graph, &est, &cfg).best
                }
                "gnn" => match &rt {
                    Some(rt) => {
                        let fallback = crate::estimator::AnalyticalFused::from_profile(&p.profile);
                        let params = super::gnn_pipeline::load_trained_params(&rt.manifest.dir);
                        let pred = match params {
                            Some(ps) => crate::runtime::gnn::GnnPredictor::with_params(
                                rt, ps, fallback,
                            )?,
                            None => crate::runtime::gnn::GnnPredictor::load(rt, fallback)?,
                        };
                        let est = crate::estimator::CostEstimator::new(&p.profile, Box::new(pred));
                        backtracking_search(&p.graph, &est, &cfg).best
                    }
                    None => p.graph.clone(), // no artifacts: identity
                },
                _ => {
                    let est = p.estimator(EstimatorKind::Oracle);
                    backtracking_search(&p.graph, &est, &cfg).best
                }
            };
            oracle.prepare(&best);
            row.push(fmt_ms(simulate(&best, &oracle, SimOptions::default()).makespan_ms));
        }
        t.row(row);
    }
    Ok(t.to_markdown())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> BenchOptions {
        BenchOptions { scale: Scale::Fast, ..Default::default() }
    }

    #[test]
    fn fig8_produces_rows_for_all_models() {
        let md = fig8(&tiny_opts());
        for kind in ModelKind::ALL {
            assert!(md.contains(kind.name()), "{md}");
        }
    }

    #[test]
    fn table3_has_three_variants() {
        // Smoke on one model worth of work is enough: restrict via a
        // custom sweep call.
        let md = sweep_table(
            &tiny_opts(),
            "t",
            &[("α=1", 1.0, None), ("α=1.05", 1.05, None)],
        );
        assert!(md.contains("α=1"));
        assert!(md.contains("vgg19"));
    }
}

// ---------------------------------------------------------------------------
// Extensions beyond the paper's evaluation (DESIGN.md §5 "designed
// ablations" + §8 future work).
// ---------------------------------------------------------------------------

/// Extension A — search-algorithm ablation: the paper's backtracking
/// search vs simulated annealing over the identical move set and cost
/// model (equal evaluation budgets).
pub fn ext_search_ablation(opts: &BenchOptions) -> String {
    use crate::search::anneal::{anneal_search, AnnealConfig};
    let cluster = Cluster::cluster_a();
    let mut t = Table::new(
        "Extension A — backtracking (Alg. 1) vs simulated annealing (ms / evals)",
        &["model", "initial", "backtracking", "annealing"],
    );
    for kind in [ModelKind::ResNet50, ModelKind::Transformer, ModelKind::Rnnlm] {
        let p = prepare(opts, kind, &cluster);
        let est = p.estimator(opts.estimator);
        let bt = backtracking_search(&p.graph, &est, &opts.search_config());
        let acfg = AnnealConfig {
            steps: (bt.evals as usize).max(200),
            seed: opts.seed,
            ..Default::default()
        };
        let an = anneal_search(&p.graph, &est, &acfg);
        t.row(vec![
            kind.name().to_string(),
            fmt_ms(bt.initial_cost_ms),
            format!("{}/{}", fmt_ms(bt.best_cost_ms), bt.evals),
            format!("{}/{}", fmt_ms(an.best_cost_ms), an.evals),
        ]);
    }
    t.to_markdown()
}

/// Extension B — parameter-server vs ring AllReduce (paper §8): the same
/// DisCo-optimized module timed under both communication substrates, for
/// several server counts.
pub fn ext_parameter_server(opts: &BenchOptions) -> String {
    use crate::network::ps::{PsCostSource, PsModel};
    let cluster = Cluster::cluster_a();
    let mut t = Table::new(
        "Extension B — per-iteration time (ms): ring AllReduce vs parameter server",
        &["model", "AllReduce", "PS S=1", "PS S=4", "PS S=12"],
    );
    for kind in [ModelKind::Vgg19, ModelKind::ResNet50, ModelKind::Transformer] {
        let p = prepare(opts, kind, &cluster);
        let est = p.estimator(opts.estimator);
        let r = backtracking_search(&p.graph, &est, &opts.search_config());
        let ring = simulate(&r.best, &est, SimOptions::default());
        let mut row = vec![kind.name().to_string(), fmt_ms(ring.makespan_ms)];
        for servers in [1usize, 4, 12] {
            let src = PsCostSource { inner: &est, ps: PsModel::from_cluster(&cluster, servers) };
            let sim = simulate(&r.best, &src, SimOptions::default());
            row.push(fmt_ms(sim.makespan_ms));
        }
        t.row(row);
    }
    t.to_markdown()
}

/// §Perf — search hot-path A/B: evals/sec and peak candidate-arena bytes
/// across three engine generations — "before" (PR-0: eager clone arena,
/// per-eval scratch allocations, full candidate re-enumeration, serial
/// eval), "after" (PR-1: allocation-free, full simulation per candidate)
/// and "delta" (current: flat cost tables + checkpointed delta
/// simulation) — plus the estimator prediction-memo counters
/// (hits/misses/evictions; the memo is bounded with FIFO eviction).
/// Also writes `BENCH_search.json` at the repo root.
pub fn perf_search(opts: &BenchOptions) -> String {
    let (record, path) = match super::write_search_perf_record(opts) {
        Ok(ok) => ok,
        Err(e) => return format!("perf record failed to write: {e}\n"),
    };
    let mut t = Table::new(
        &format!(
            "§Perf — search hot path, {} on {} workers (budget {}, seed {:#x})",
            record.model, record.workers, record.unchanged_limit, record.seed
        ),
        &[
            "engine",
            "evals",
            "resims",
            "seconds",
            "evals/sec",
            "peak arena MB",
            "best (ms)",
            "cache h/m/evict",
        ],
    );
    for (name, m) in [
        ("before", &record.before),
        ("after", &record.after),
        ("delta", &record.delta),
    ] {
        t.row(vec![
            name.to_string(),
            m.evals.to_string(),
            m.resims.to_string(),
            format!("{:.2}", m.seconds),
            format!("{:.0}", m.evals_per_sec),
            format!("{:.2}", m.peak_arena_bytes as f64 / 1e6),
            fmt_ms(m.best_cost_ms),
            format!("{}/{}/{}", m.cache_hits, m.cache_misses, m.cache_evictions),
        ]);
    }
    let mut out = t.to_markdown();
    out.push_str(&format!(
        "\nafter/before throughput: {:.2}x; delta/after throughput: {:.2}x; arena ratio: {:.2}x; record: {}\n",
        record.throughput_ratio(),
        record.delta_ratio(),
        record.arena_ratio(),
        path.display()
    ));
    out
}

/// Extension C — peak activation memory: fusion's memory benefit (paper
/// §2.2 "eliminates device memory allocations for intermediate results")
/// made measurable by the simulator's refcounting.
pub fn ext_memory(opts: &BenchOptions) -> String {
    let cluster = Cluster::cluster_a();
    let mut t = Table::new(
        "Extension C — peak transient memory (MB) per scheme",
        &["model", "no_fusion", "JAX_default", "DisCo"],
    );
    for kind in [ModelKind::Vgg19, ModelKind::ResNet50, ModelKind::Transformer, ModelKind::Bert] {
        let p = prepare(opts, kind, &cluster);
        let est = p.estimator(opts.estimator);
        let mb = |g: &crate::graph::TrainingGraph| {
            est.prepare(g);
            format!("{:.0}", simulate(g, &est, SimOptions::default()).peak_bytes / 1e6)
        };
        let r = backtracking_search(&p.graph, &est, &opts.search_config());
        t.row(vec![
            kind.name().to_string(),
            mb(&p.graph),
            mb(&baselines::jax_default(&p.graph)),
            mb(&r.best),
        ]);
    }
    t.to_markdown()
}
