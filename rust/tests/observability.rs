//! Observability integration tests (DESIGN.md §15): exporter
//! well-formedness for every emitter, per-rank enactment timelines under
//! a known chaos kill plan, search convergence-curve exactness, and the
//! registry-backed service metrics surface.

use disco::coordinator::{enact, rank_track, EnactConfig, FaultPlan, LEADER_TRACK};
use disco::device::DeviceModel;
use disco::estimator::CostEstimator;
use disco::graph::builder::GraphBuilder;
use disco::graph::{OpKind, Role, TrainingGraph};
use disco::models::{build, ModelKind, ModelSpec};
use disco::network::Cluster;
use disco::search::{backtracking_search_traced, SearchConfig};
use disco::service::{request, ServeOptions, Server, WarmOptions};
use disco::util::json::Json;
use disco::util::trace::{to_chrome_json, to_jsonl, Event, MemSink, Ph, TrackId};

// ---------------------------------------------------------------------------
// Shared helpers
// ---------------------------------------------------------------------------

/// Small fusion-rich training graph (mirrors tests/service.rs).
fn workload() -> TrainingGraph {
    let mut b = GraphBuilder::new("obs-wl", 8);
    let x = b.constant("x", &[1 << 14]);
    let mut prev = x;
    for i in 0..4 {
        let m = b.compute(OpKind::Mul, &format!("m{i}"), &[prev], &[1 << 14], Role::Forward);
        let t = b.compute(OpKind::Tanh, &format!("t{i}"), &[m], &[1 << 14], Role::Forward);
        prev = t;
    }
    let mut grad = prev;
    for i in 0..4 {
        let gop = b.compute(OpKind::Mul, &format!("bg{i}"), &[grad], &[1 << 10], Role::Backward);
        let p = b.param(&format!("w{i}"), &[1 << 10]);
        let ar = b.allreduce(&format!("ar{i}"), gop, &[1 << 10]);
        b.optimizer_update(&format!("u{i}"), &[ar, p]);
        grad = gop;
    }
    b.finish()
}

fn tiny_model() -> TrainingGraph {
    build(&ModelSpec { kind: ModelKind::Rnnlm, batch: 8, depth_scale: 0.15 }, 4)
}

/// Well-formedness contract every exporter must satisfy — valid JSON,
/// metadata rows labeling real tracks, file-order monotone timestamps,
/// and non-overlapping spans within each lane.
fn assert_chrome_well_formed(json: &str, expect_tracks: usize) -> Vec<Json> {
    let parsed = Json::parse(json).expect("chrome trace must be valid JSON");
    let rows = parsed.get("traceEvents").as_arr().expect("traceEvents array").clone();
    let meta: Vec<&Json> =
        rows.iter().filter(|r| r.get("ph").as_str() == Some("M")).collect();
    assert_eq!(meta.len(), expect_tracks, "one thread_name row per track");
    for m in &meta {
        assert!(m.get("args").get("name").as_str().is_some(), "unlabeled track: {m:?}");
    }
    let events: Vec<&Json> =
        rows.iter().filter(|r| r.get("ph").as_str() != Some("M")).collect();
    let mut last_ts = f64::NEG_INFINITY;
    for e in &events {
        let ph = e.get("ph").as_str().unwrap();
        assert!(ph == "X" || ph == "i", "unknown phase {ph}");
        let ts = e.get("ts").as_f64().unwrap();
        assert!(ts >= last_ts, "timestamps regress in file order");
        last_ts = ts;
        if ph == "X" {
            assert!(e.get("dur").as_f64().unwrap() >= 0.0);
        }
    }
    // Spans on the same (pid, tid) lane never overlap.
    let mut lanes: std::collections::BTreeMap<(u64, u64), Vec<(f64, f64)>> = Default::default();
    for e in &events {
        if e.get("ph").as_str() == Some("X") {
            let key =
                (e.get("pid").as_f64().unwrap() as u64, e.get("tid").as_f64().unwrap() as u64);
            let ts = e.get("ts").as_f64().unwrap();
            lanes.entry(key).or_default().push((ts, ts + e.get("dur").as_f64().unwrap()));
        }
    }
    for (lane, spans) in lanes {
        for w in spans.windows(2) {
            assert!(
                w[1].0 >= w[0].1 - 1e-6,
                "lane {lane:?}: span starting {} overlaps one ending {}",
                w[1].0,
                w[0].1
            );
        }
    }
    rows
}

fn events_on(events: &[Event], track: TrackId) -> Vec<Event> {
    let mut v: Vec<Event> =
        events.iter().filter(|e| e.track == track).cloned().collect();
    v.sort_by(|a, b| a.ts_ms.partial_cmp(&b.ts_ms).unwrap());
    v
}

// ---------------------------------------------------------------------------
// Search telemetry
// ---------------------------------------------------------------------------

#[test]
fn search_trace_exports_are_well_formed_and_exact() {
    let g = workload();
    let device = DeviceModel::gtx1080ti();
    let cluster = Cluster::cluster_a();
    let prof = disco::profiler::profile(&g, &device, &cluster, 1, 7);
    let est = CostEstimator::oracle(&prof, &device);
    let cfg = SearchConfig {
        unchanged_limit: 40,
        max_queue: 64,
        seed: 7,
        trace: true,
        ..Default::default()
    };
    let mut sink = MemSink::default();
    let r = backtracking_search_traced(&g, &est, &cfg, &[], &mut sink);

    // Chrome export: one labeled search track, monotone, non-overlapping.
    assert_chrome_well_formed(&to_chrome_json(&sink.events, &sink.tracks), 1);
    // One step span per dequeue step, framed by initial/final instants.
    let steps = sink.events.iter().filter(|e| e.cat == "search-step").count();
    assert_eq!(steps as u64, r.steps, "one span per search step");
    assert_eq!(sink.events.first().unwrap().name, "initial");
    assert_eq!(sink.events.last().unwrap().name, "final");

    // Convergence JSONL: every line parses; the final record's best_ms
    // survives the JSON round-trip bit-exactly equal to the result.
    let jsonl = to_jsonl(&sink.events);
    let lines: Vec<&str> = jsonl.lines().collect();
    assert_eq!(lines.len(), sink.events.len());
    let mut best_seen = f64::INFINITY;
    for line in &lines {
        let row = Json::parse(line).expect("JSONL line must parse");
        if let Some(b) = row.get("best_ms").as_f64() {
            assert!(b <= best_seen + 1e-12, "convergence curve must not regress");
            best_seen = b;
        }
    }
    let last = Json::parse(lines.last().unwrap()).unwrap();
    assert_eq!(last.get("name").as_str(), Some("final"));
    assert_eq!(
        last.get("best_ms").as_f64(),
        Some(r.best_cost_ms),
        "tail -1 of the curve IS the final makespan, exactly"
    );
    assert_eq!(last.get("evals").as_f64(), Some(r.evals as f64));
}

// ---------------------------------------------------------------------------
// Enactment tracing
// ---------------------------------------------------------------------------

#[test]
fn enact_trace_chaos_kill_ends_rank_lane_with_retire() {
    let g = tiny_model();
    let seed = 0xC0DE;
    let cfg = EnactConfig {
        world: 3,
        iterations: 2,
        seed,
        quorum: 1,
        phase_timeout_ms: 5_000,
        max_rank_retries: 0, // no re-admission: the kill is final
        fault: Some(FaultPlan::parse("kill@1:1", seed).unwrap()),
        trace: true,
        ..Default::default()
    };
    let report = enact(&g, &cfg).expect("quorum of survivors must succeed");
    assert!(report.degraded, "killed rank must degrade the round");
    assert!(report.failed_ranks.contains(&1));

    // One leader phase track plus one track per rank, all labeled.
    assert_eq!(report.trace_tracks.len(), 4);
    let labels: Vec<&str> =
        report.trace_tracks.iter().map(|(_, n)| n.as_str()).collect();
    assert!(labels.contains(&"leader"));
    for r in 0..3 {
        assert!(labels.contains(&format!("rank {r}").as_str()), "missing rank {r} label");
    }
    let rows = assert_chrome_well_formed(
        &to_chrome_json(&report.trace_events, &report.trace_tracks),
        4,
    );
    assert!(rows.len() > 4, "trace must contain real events");

    // Leader lane: the three phase spans, in protocol order.
    let phases: Vec<String> = events_on(&report.trace_events, LEADER_TRACK)
        .iter()
        .filter(|e| e.ph == Ph::Span)
        .map(|e| e.name.clone())
        .collect();
    assert_eq!(phases, ["join", "ack", "run"]);

    // Surviving ranks ran both iterations on their own lanes.
    for r in [0usize, 2] {
        let lane = events_on(&report.trace_events, rank_track(r));
        let iters = lane.iter().filter(|e| e.cat == "iter").count();
        assert_eq!(iters, 2, "rank {r} iteration spans");
        assert!(lane.iter().any(|e| e.name == "join"));
        assert!(lane.iter().any(|e| e.name == "report"));
        assert!(!lane.iter().any(|e| e.name.starts_with("retire")));
    }

    // The killed rank's lane ends with its retire instant: the worker
    // stops emitting at the kill, so the leader-side retirement is the
    // last thing on the timeline.
    let lane = events_on(&report.trace_events, rank_track(1));
    assert!(lane.iter().any(|e| e.name == "join"), "rank 1 joined before dying");
    assert_eq!(
        lane.iter().filter(|e| e.cat == "iter").count(),
        1,
        "rank 1 completed exactly iteration 0 before the kill"
    );
    let last = lane.last().unwrap();
    assert!(
        last.name.starts_with("retire"),
        "rank 1's lane must end with the retire event, got {:?}",
        last.name
    );
    assert_eq!(last.ph, Ph::Instant);
}

#[test]
fn enact_trace_toggle_is_pure_observation() {
    let g = tiny_model();
    let base = EnactConfig {
        world: 2,
        iterations: 2,
        seed: 0x0B5,
        phase_timeout_ms: 5_000,
        ..Default::default()
    };
    let off = enact(&g, &base).unwrap();
    assert!(off.trace_events.is_empty() && off.trace_tracks.is_empty());
    let on = enact(&g, &EnactConfig { trace: true, ..base }).unwrap();
    assert!(!on.trace_events.is_empty());
    // Measurements are wall-clock-free simulator output — identical.
    assert_eq!(off.per_rank, on.per_rank);
    assert_eq!(off.iteration_ms, on.iteration_ms);
    assert_eq!(off.acks, on.acks);
}

// ---------------------------------------------------------------------------
// Service metrics
// ---------------------------------------------------------------------------

fn plan_request(graph: &TrainingGraph) -> Json {
    Json::obj(vec![
        ("cmd", Json::Str("plan".into())),
        ("graph", graph.to_json_value()),
        ("cluster", Json::Str("a".into())),
        ("estimator", Json::Str("oracle".into())),
        ("seed", Json::Num(7.0)),
        ("unchanged", Json::Num(40.0)),
    ])
}

#[test]
fn serve_metrics_exposition_tracks_the_stats_surface() {
    let server = Server::bind(&ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        store_path: None,
        capacity: 32,
        warm: WarmOptions::default(),
        ..ServeOptions::default()
    })
    .unwrap();
    let addr = server.local_addr().to_string();
    let handle = std::thread::spawn(move || server.run().unwrap());

    let g = workload();
    let first = request(&addr, &plan_request(&g)).unwrap();
    assert_eq!(first.get("source").as_str(), Some("cold"));
    let second = request(&addr, &plan_request(&g)).unwrap();
    assert_eq!(second.get("source").as_str(), Some("store"));

    // The `metrics` wire op returns a text exposition of the registry.
    let m = request(&addr, &Json::obj(vec![("cmd", Json::Str("metrics".into()))])).unwrap();
    assert_eq!(m.get("ok").as_bool(), Some(true));
    let text = m.get("exposition").as_str().unwrap();
    assert!(text.contains("# TYPE disco_requests_total counter"));
    assert!(text.contains("# TYPE disco_resolve_ms histogram"));
    assert!(text.contains("disco_searches_total 1\n"));
    assert!(text.contains("disco_store_hits_total 1\n"));
    // Per-path split: one cold resolve, one store hit, no warm starts.
    assert!(text.contains("disco_resolve_cold_ms_count 1\n"));
    assert!(text.contains("disco_resolve_hit_ms_count 1\n"));
    assert!(text.contains("disco_resolve_warm_ms_count 0\n"));
    assert!(text.contains("disco_resolve_ms_count 2\n"));
    // The cold search persisted one record — store I/O was timed.
    assert!(text.contains("disco_store_put_ms_count 1\n"));
    assert!(text.contains("disco_resolve_ms_bucket{le=\"+Inf\"} 2\n"));

    // The stats surface reads the same registry: identical counts, and
    // percentiles that are log₂ bucket upper bounds covering the sum.
    let stats = request(&addr, &Json::obj(vec![("cmd", Json::Str("stats".into()))])).unwrap();
    assert_eq!(stats.get("searches").as_usize(), Some(1));
    assert_eq!(stats.get("store_hits").as_usize(), Some(1));
    assert_eq!(stats.get("resolve_samples").as_usize(), Some(2));
    let p50 = stats.get("resolve_p50_ms").as_f64().unwrap();
    let p99 = stats.get("resolve_p99_ms").as_f64().unwrap();
    assert!(p50 > 0.0 && p99 >= p50, "p50 {p50}, p99 {p99}");

    let _ = request(&addr, &Json::obj(vec![("cmd", Json::Str("shutdown".into()))])).unwrap();
    handle.join().unwrap();
}
