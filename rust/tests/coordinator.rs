//! Integration: leader/worker enactment over real TCP sockets.

use disco::coordinator::{enact, EnactConfig};
use disco::device::DeviceModel;
use disco::models::{build, ModelKind, ModelSpec};
use disco::network::Cluster;

fn small_model() -> disco::graph::TrainingGraph {
    build(
        &ModelSpec { kind: ModelKind::Rnnlm, batch: 16, depth_scale: 0.2 },
        12,
    )
}

#[test]
fn enactment_broadcast_and_report() {
    let g = small_model();
    let cfg = EnactConfig { world: 4, iterations: 3, ..Default::default() };
    let report = enact(&g, &cfg).unwrap();
    assert_eq!(report.acks, 4);
    assert_eq!(report.per_rank.len(), 4);
    // A fault-free round is clean: nothing degraded, nobody failed,
    // every in-process worker thread joined.
    assert!(!report.degraded);
    assert!(report.failed_ranks.is_empty());
    assert_eq!(report.workers_joined, 4);
    // Every worker executed and reported a positive makespan.
    for (makespan, comp, comm) in &report.per_rank {
        assert!(*makespan > 0.0);
        assert!(*comp > 0.0);
        assert!(*comm > 0.0);
    }
    // Synchronous iteration time = slowest rank.
    let max = report.per_rank.iter().map(|r| r.0).fold(0.0f64, f64::max);
    assert_eq!(report.iteration_ms, max);
}

#[test]
fn enactment_is_seed_deterministic() {
    let g = small_model();
    let cfg = EnactConfig { world: 2, iterations: 2, seed: 99, ..Default::default() };
    let a = enact(&g, &cfg).unwrap();
    let b = enact(&g, &cfg).unwrap();
    assert_eq!(a.per_rank, b.per_rank);
}

#[test]
fn enactment_differs_across_clusters() {
    let g = small_model();
    let a = enact(
        &g,
        &EnactConfig { world: 2, iterations: 2, cluster: Cluster::cluster_a(), ..Default::default() },
    )
    .unwrap();
    let b = enact(
        &g,
        &EnactConfig {
            world: 2,
            iterations: 2,
            cluster: Cluster::cluster_b(),
            device: DeviceModel::tesla_t4(),
            ..Default::default()
        },
    )
    .unwrap();
    assert_ne!(a.iteration_ms, b.iteration_ms);
}

#[test]
fn optimized_strategy_enacts_faster() {
    // The end-to-end claim at small scale: run DisCo's search, then
    // enact both the original and optimized modules; optimized should
    // not be slower (hi-fi noise notwithstanding — use multiple iters).
    let g = small_model();
    let device = DeviceModel::gtx1080ti();
    let cluster = Cluster::cluster_a();
    let prof = disco::profiler::profile(&g, &device, &cluster, 3, 7);
    let est = disco::estimator::CostEstimator::oracle(&prof, &device);
    let cfg = disco::search::SearchConfig {
        unchanged_limit: 80,
        max_queue: 64,
        seed: 3,
        ..Default::default()
    };
    let result = disco::search::backtracking_search(&g, &est, &cfg);
    assert!(result.best_cost_ms < result.initial_cost_ms);

    let ecfg = EnactConfig { world: 3, iterations: 5, ..Default::default() };
    let before = enact(&g, &ecfg).unwrap();
    let after = enact(&result.best, &ecfg).unwrap();
    assert!(
        after.iteration_ms < before.iteration_ms * 1.02,
        "optimized {:.3}ms vs original {:.3}ms",
        after.iteration_ms,
        before.iteration_ms
    );
}
