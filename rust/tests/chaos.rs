//! Chaos property suite: the enactment protocol under deterministic,
//! seeded fault injection (DESIGN.md §12).
//!
//! The contract under test — for ANY seeded fault plan:
//! * `enact()` never blocks past its per-phase deadlines (plus a bounded
//!   shutdown/join tail);
//! * it returns a (possibly `degraded`) report when survivors ≥ quorum,
//!   and a typed `EnactError::QuorumLost` otherwise;
//! * every in-process worker thread is joined before it returns
//!   (`workers_joined` == world — no leaks on either path).

use disco::coordinator::{
    enact, EnactConfig, EnactError, Fault, FaultPlan, Phase, RankState,
};
use disco::models::{build, ModelKind, ModelSpec};
use disco::util::rng::Rng;
use std::time::{Duration, Instant};

fn tiny_model() -> disco::graph::TrainingGraph {
    build(&ModelSpec { kind: ModelKind::Rnnlm, batch: 8, depth_scale: 0.15 }, 4)
}

/// Generate one random-but-seeded fault plan. Parameters are constrained
/// to ranges that exercise every code path without padding the suite
/// with full-deadline waits: drop budgets always let Hello through,
/// delays stay well under the phase budget, kills target real iterations.
fn gen_plan(rng: &mut Rng, world: usize, case: u64) -> FaultPlan {
    let mut faults = Vec::new();
    for rank in 0..world {
        if rng.gen_f64() < 0.35 {
            faults.push(match rng.gen_range(4) {
                0 => Fault::KillAtIter { rank, iter: rng.gen_range(2) },
                1 => Fault::DropAfterBytes { rank, bytes: 64 + rng.gen_range(4096) as u64 },
                2 => Fault::DelayMs { rank, ms: 20 + rng.gen_range(100) as u64 },
                _ => Fault::CorruptFrame { rank, nth: 1 + rng.gen_range(2) },
            });
        }
    }
    FaultPlan { seed: case, faults }
}

#[test]
fn chaos_property_seeded_plans() {
    const CASES: u64 = 50;
    const PT_MS: u64 = 1200;
    let g = tiny_model();
    let mut rng = Rng::new(0xC4A05);
    let (mut clean, mut degraded, mut quorum_lost) = (0u32, 0u32, 0u32);
    for case in 0..CASES {
        let world = rng.gen_range_inclusive(2, 4);
        let quorum = rng.gen_range_inclusive(1, world);
        let retries = rng.gen_range(2); // 0 or 1
        let plan = gen_plan(&mut rng, world, case);
        let cfg = EnactConfig {
            world,
            iterations: 2,
            seed: 0xC0DE ^ case,
            quorum,
            phase_timeout_ms: PT_MS,
            max_rank_retries: retries,
            fault: Some(plan.clone()),
            ..Default::default()
        };
        let start = Instant::now();
        let res = enact(&g, &cfg);
        let elapsed = start.elapsed();
        // Deadline bound: 3 phases × PT plus a bounded shutdown/join
        // tail (reconnect budgets, worker idle deadlines).
        assert!(
            elapsed < Duration::from_millis(3 * PT_MS + 4000),
            "case {case} (plan '{}'): enact blocked for {elapsed:?}",
            plan.to_spec()
        );
        match res {
            Ok(r) => {
                let reported =
                    r.status.iter().filter(|s| s.state == RankState::Ok).count();
                assert!(
                    reported >= quorum,
                    "case {case}: Ok with {reported} < quorum {quorum}"
                );
                assert_eq!(
                    r.degraded,
                    !r.failed_ranks.is_empty(),
                    "case {case}: degraded flag inconsistent"
                );
                assert_eq!(r.per_rank.len(), world);
                assert_eq!(r.status.len(), world);
                assert_eq!(
                    r.workers_joined, world,
                    "case {case}: leaked worker threads"
                );
                // Reporting ranks carry real measurements; failed ranks
                // carry zeros.
                for s in &r.status {
                    if s.state == RankState::Ok {
                        assert!(s.makespan_ms > 0.0, "case {case} rank {}", s.rank);
                    } else {
                        assert!(r.failed_ranks.contains(&s.rank));
                    }
                }
                if r.degraded {
                    degraded += 1;
                } else {
                    clean += 1;
                }
            }
            Err(EnactError::QuorumLost { live, quorum: q, .. }) => {
                assert!(live < q, "case {case}: QuorumLost with live {live} >= {q}");
                quorum_lost += 1;
            }
            Err(e) => panic!("case {case} (plan '{}'): unexpected error {e}", plan.to_spec()),
        }
    }
    assert_eq!(clean + degraded + quorum_lost, CASES as u32);
    // The generator must actually exercise all three outcomes; a chaos
    // suite where nothing ever fails (or nothing ever succeeds) is
    // testing the wrong distribution.
    assert!(clean > 0, "no clean runs across {CASES} cases");
    assert!(
        degraded + quorum_lost > 0,
        "no faulted outcomes across {CASES} cases"
    );
}

#[test]
fn killed_rank_degrades_but_quorum_succeeds() {
    let g = tiny_model();
    let cfg = EnactConfig {
        world: 4,
        iterations: 2,
        quorum: 3,
        phase_timeout_ms: 5000,
        max_rank_retries: 0,
        fault: Some(FaultPlan::parse("kill@3:1", 7).unwrap()),
        ..Default::default()
    };
    let r = enact(&g, &cfg).unwrap();
    assert!(r.degraded);
    assert_eq!(r.failed_ranks, vec![3]);
    assert_eq!(r.workers_joined, 4);
    for rank in 0..3 {
        assert_eq!(r.status[rank].state, RankState::Ok);
        assert!(r.per_rank[rank].0 > 0.0);
    }
    assert!(matches!(r.status[3].state, RankState::Retired(_)));
    assert_eq!(r.per_rank[3], (0.0, 0.0, 0.0));
    // The victim ran iteration 0 and heartbeat before dying at
    // iteration 1 — the liveness plumbing must have seen it.
    assert_eq!(r.status[3].heartbeats, 1);
}

#[test]
fn readmitted_rank_completes_clean() {
    let g = tiny_model();
    let cfg = EnactConfig {
        world: 3,
        iterations: 2,
        quorum: 0, // all
        phase_timeout_ms: 5000,
        max_rank_retries: 1,
        fault: Some(FaultPlan::parse("kill@1:0", 11).unwrap()),
        ..Default::default()
    };
    let r = enact(&g, &cfg).unwrap();
    // The killed rank reconnected, re-acked from cached strategy state,
    // and completed — the round is NOT degraded.
    assert!(!r.degraded, "status: {:?}", r.status);
    assert!(r.failed_ranks.is_empty());
    assert_eq!(r.acks, 3);
    assert_eq!(r.status[1].reconnects, 1, "rank 1 must have been re-admitted once");
    assert_eq!(r.status[1].state, RankState::Ok);
    assert!(r.per_rank[1].0 > 0.0);
    assert_eq!(r.status[0].reconnects, 0);
    assert_eq!(r.status[2].reconnects, 0);
}

#[test]
fn below_quorum_returns_typed_error_fast() {
    let g = tiny_model();
    let pt = 5000u64;
    let cfg = EnactConfig {
        world: 3,
        iterations: 2,
        quorum: 2,
        phase_timeout_ms: pt,
        max_rank_retries: 0,
        fault: Some(FaultPlan::parse("kill@0:0,kill@1:0", 13).unwrap()),
        ..Default::default()
    };
    let start = Instant::now();
    let err = enact(&g, &cfg).unwrap_err();
    let elapsed = start.elapsed();
    match err {
        EnactError::QuorumLost { phase, live, quorum, failed } => {
            // The deaths land right after the Run frames go out, so the
            // loss is detected in the ack or run phase depending on poll
            // order — never join (everyone said Hello).
            assert_ne!(phase, Phase::Join);
            assert_eq!(live, 1);
            assert_eq!(quorum, 2);
            assert_eq!(failed, vec![0, 1]);
        }
        other => panic!("expected QuorumLost, got {other}"),
    }
    // Fail-fast: two dead sockets are detected immediately, not at the
    // phase deadline.
    assert!(
        elapsed < Duration::from_millis(pt),
        "quorum loss took {elapsed:?} — waited for the deadline instead of failing fast"
    );
}

#[test]
fn delay_straggler_retired_when_configured() {
    let g = tiny_model();
    let cfg = EnactConfig {
        world: 3,
        iterations: 2,
        quorum: 2,
        phase_timeout_ms: 3000,
        max_rank_retries: 0,
        straggler_timeout_ms: 120,
        fault: Some(FaultPlan::parse("delay@2:300", 17).unwrap()),
        ..Default::default()
    };
    let r = enact(&g, &cfg).unwrap();
    assert!(r.degraded);
    assert_eq!(r.failed_ranks, vec![2]);
    match &r.status[2].state {
        RankState::Retired(reason) => {
            assert!(reason.contains("straggler"), "reason: {reason}")
        }
        other => panic!("expected straggler retirement, got {other:?}"),
    }
    assert_eq!(r.status[0].state, RankState::Ok);
    assert_eq!(r.status[1].state, RankState::Ok);
}

#[test]
fn no_workers_at_all_fails_in_join_phase() {
    let g = tiny_model();
    let pt = 300u64;
    let cfg = EnactConfig {
        world: 2,
        iterations: 1,
        spawn_inproc: false, // nobody will ever connect
        quorum: 1,
        phase_timeout_ms: pt,
        ..Default::default()
    };
    let start = Instant::now();
    let err = enact(&g, &cfg).unwrap_err();
    assert!(matches!(err, EnactError::QuorumLost { phase: Phase::Join, live: 0, .. }), "{err}");
    let elapsed = start.elapsed();
    assert!(
        elapsed >= Duration::from_millis(pt) && elapsed < Duration::from_millis(4 * pt + 1000),
        "join-phase timeout not respected: {elapsed:?}"
    );
}

#[test]
fn same_plan_same_seed_is_reproducible() {
    // The determinism claim behind "every chaos failure shrinks to a
    // one-line spec": identical config + plan ⇒ identical disposition.
    let g = tiny_model();
    let mk = || EnactConfig {
        world: 3,
        iterations: 2,
        quorum: 2,
        phase_timeout_ms: 5000,
        max_rank_retries: 0,
        fault: Some(FaultPlan::parse("kill@1:0", 23).unwrap()),
        ..Default::default()
    };
    let a = enact(&g, &mk()).unwrap();
    let b = enact(&g, &mk()).unwrap();
    assert_eq!(a.degraded, b.degraded);
    assert_eq!(a.failed_ranks, b.failed_ranks);
    assert_eq!(a.per_rank, b.per_rank, "surviving ranks must report identical timings");
}

#[test]
fn invalid_chaos_config_is_typed() {
    let g = tiny_model();
    let err = enact(&g, &EnactConfig { world: 0, ..Default::default() }).unwrap_err();
    assert!(matches!(err, EnactError::Config(_)), "{err}");
    let err =
        enact(&g, &EnactConfig { world: 2, quorum: 3, ..Default::default() }).unwrap_err();
    assert!(matches!(err, EnactError::Config(_)), "{err}");
}
