//! Property tests (in-tree harness, DESIGN.md §7): invariants over random
//! graphs, fusion sequences, simulation, collectives and the coordinator.

use disco::collective::run_workers;
use disco::device::DeviceModel;
use disco::estimator::CostEstimator;
use disco::fusion::{self, CandidateSet, FusionKind, Mutation};
use disco::graph::builder::GraphBuilder;
use disco::graph::{CollectiveKind, NodeId, OpKind, Role, ShardSpec, TrainingGraph};
use disco::network::Cluster;
use disco::prop_assert;
use disco::search::{backtracking_search, SearchConfig};
use disco::sim::{
    fo_bound, simulate, simulate_ckpt_in, simulate_delta, simulate_in, simulate_table_in,
    CheckpointLog, CostSource, CostTable, NoRecord, SimOptions, SimWorkspace,
};
use disco::util::prop::{check, CaseResult, PropConfig};
use disco::util::rng::Rng;

/// Random layered DAG with gradients + AllReduces, structurally similar to
/// a BP graph.
fn random_graph(rng: &mut Rng) -> TrainingGraph {
    random_graph_elems(rng, 256)
}

/// [`random_graph`] with a configurable base tensor width. The chunking
/// properties use larger tensors (`elems = 8192` → 4-32 KiB gradients)
/// because the vocabulary's `MIN_CHUNK_BYTES` floor correctly refuses to
/// chunk the default 256-element (≤ 1 KiB) gradients.
fn random_graph_elems(rng: &mut Rng, elems: usize) -> TrainingGraph {
    let layers = rng.gen_range_inclusive(2, 6);
    let width = rng.gen_range_inclusive(1, 4);
    let mut b = GraphBuilder::new("prop", rng.gen_range_inclusive(2, 16));
    let mut prev: Vec<usize> = vec![b.constant("x", &[elems])];
    let kinds = [OpKind::Mul, OpKind::Add, OpKind::Tanh, OpKind::Sigmoid, OpKind::MatMul, OpKind::Reduce];
    for l in 0..layers {
        let mut cur = Vec::new();
        for w in 0..width {
            let k = *rng.choose(&kinds).unwrap();
            // 1-2 inputs from the previous layer.
            let mut ins = vec![prev[rng.gen_range(prev.len())]];
            if rng.gen_bool(0.4) {
                let extra = prev[rng.gen_range(prev.len())];
                if !ins.contains(&extra) {
                    ins.push(extra);
                }
            }
            let dims = [elems >> rng.gen_range(3)];
            let id = b.compute(k, &format!("l{l}w{w}"), &ins, &dims, if l >= layers / 2 { Role::Backward } else { Role::Forward }, );
            cur.push(id);
        }
        prev = cur;
    }
    // Gradient sync for a random subset of backward nodes.
    let g = b.graph().clone();
    let bwd: Vec<usize> = g
        .live()
        .filter(|n| n.role == Role::Backward)
        .map(|n| n.id)
        .collect();
    for (i, &id) in bwd.iter().enumerate() {
        if rng.gen_bool(0.7) {
            let dims: Vec<usize> = b.graph().nodes[id].shape.dims.clone();
            let p = b.param(&format!("w{i}"), &dims);
            let ar = b.allreduce(&format!("ar{i}"), id, &dims);
            b.optimizer_update(&format!("u{i}"), &[ar, p]);
        }
    }
    b.finish()
}

/// Apply a random sequence of fusion rewrites; returns how many succeeded.
fn random_rewrites(g: &mut TrainingGraph, rng: &mut Rng, tries: usize) -> usize {
    let mut applied = 0;
    for _ in 0..tries {
        if rng.gen_bool(0.6) {
            let cands = fusion::op_fusion_candidates(g);
            if let Some(&(p, s)) = rng.choose(&cands) {
                let kind = if rng.gen_bool(0.5) {
                    FusionKind::NonDuplicate
                } else {
                    FusionKind::Duplicate
                };
                if fusion::fuse_ops(g, p, s, kind).is_ok() {
                    applied += 1;
                }
            }
        } else {
            let ars = g.allreduces();
            if let Some(&a) = rng.choose(&ars) {
                let nbrs = fusion::ar_neighbors(g, a);
                if let Some(&bb) = rng.choose(&nbrs) {
                    if fusion::fuse_allreduce(g, a, bb).is_ok() {
                        applied += 1;
                    }
                }
            }
        }
    }
    applied
}

/// Re-chunk random AllReduces through the search vocabulary
/// ([`fusion::chunk_candidates`] + [`fusion::set_chunks`]); returns how
/// many chunkings were applied.
fn random_chunkings(g: &mut TrainingGraph, rng: &mut Rng, tries: usize) -> usize {
    let mut applied = 0;
    for _ in 0..tries {
        let ars = g.allreduces();
        let Some(&a) = rng.choose(&ars) else { break };
        let counts = fusion::chunk_candidates(g, a, fusion::MAX_CHUNKS);
        let Some(&c) = rng.choose(&counts) else { continue };
        if fusion::set_chunks(g, a, c).is_ok() && c >= 2 {
            applied += 1;
        }
    }
    applied
}

/// Re-shard random AllReduces through the search vocabulary
/// ([`fusion::shard_candidates`] + [`fusion::set_sharding`]); returns
/// how many activations (switches to reduce-scatter/all-gather) were
/// applied.
fn random_shardings(g: &mut TrainingGraph, rng: &mut Rng, tries: usize) -> usize {
    let mut applied = 0;
    for _ in 0..tries {
        let ars = g.allreduces();
        let Some(&a) = rng.choose(&ars) else { break };
        let kinds = fusion::shard_candidates(g, a);
        let Some(&k) = rng.choose(&kinds) else { continue };
        if fusion::set_sharding(g, a, k).is_ok() && k == CollectiveKind::ReduceScatterAllGather {
            applied += 1;
        }
    }
    applied
}

#[test]
fn prop_fusion_preserves_acyclicity_and_bytes() {
    check("fusion-invariants", PropConfig { cases: 96, seed: 0xAB1 }, |rng| {
        let mut g = random_graph(rng);
        let bytes = g.total_gradient_bytes();
        let repr = g.represented_ops();
        random_rewrites(&mut g, rng, 12);
        prop_assert!(g.validate().is_ok(), "graph invalid after rewrites");
        prop_assert!(
            (g.total_gradient_bytes() - bytes).abs() < 1e-6,
            "gradient bytes changed: {} -> {}",
            bytes,
            g.total_gradient_bytes()
        );
        prop_assert!(
            g.represented_ops() >= repr,
            "represented ops lost: {} -> {}",
            repr,
            g.represented_ops()
        );
        CaseResult::Pass
    });
}

struct Unit;

impl CostSource for Unit {
    fn compute_time_ms(&self, _n: &disco::graph::Node) -> f64 {
        0.5
    }
    fn comm_time_ms(&self, bytes: f64) -> f64 {
        0.1 + bytes * 1e-7
    }
}

#[test]
fn prop_sim_bounded_by_fo_and_serial_sum() {
    check("sim-bounds", PropConfig { cases: 96, seed: 0xB0B }, |rng| {
        let mut g = random_graph(rng);
        random_rewrites(&mut g, rng, 6);
        let r = simulate(&g, &Unit, SimOptions::default());
        let fo = fo_bound(&g, &Unit);
        prop_assert!(r.makespan_ms >= fo - 1e-9, "makespan {} < FO {}", r.makespan_ms, fo);
        prop_assert!(
            r.makespan_ms <= r.comp_busy_ms + r.comm_busy_ms + 1e-9,
            "makespan {} > serial {}",
            r.makespan_ms,
            r.comp_busy_ms + r.comm_busy_ms
        );
        prop_assert!(r.overlap_ratio() >= 1.0 - 1e-9, "overlap < 1");
        CaseResult::Pass
    });
}

#[test]
fn prop_sim_monotone_in_comm_cost() {
    struct Scaled(f64);
    impl CostSource for Scaled {
        fn compute_time_ms(&self, _n: &disco::graph::Node) -> f64 {
            0.5
        }
        fn comm_time_ms(&self, bytes: f64) -> f64 {
            self.0 * (0.1 + bytes * 1e-7)
        }
    }
    check("sim-monotone-comm", PropConfig { cases: 64, seed: 0xC0C }, |rng| {
        let g = random_graph(rng);
        let cheap = simulate(&g, &Scaled(1.0), SimOptions::default());
        let pricey = simulate(&g, &Scaled(3.0), SimOptions::default());
        prop_assert!(
            pricey.makespan_ms >= cheap.makespan_ms - 1e-9,
            "3x comm got faster: {} vs {}",
            pricey.makespan_ms,
            cheap.makespan_ms
        );
        CaseResult::Pass
    });
}

/// Cost source with a per-collective launch overhead, for pinning the
/// "overhead charged once, not per chunk" semantics (DESIGN.md §13).
struct Ovh;

impl CostSource for Ovh {
    fn compute_time_ms(&self, _n: &disco::graph::Node) -> f64 {
        0.5
    }
    fn comm_time_ms(&self, bytes: f64) -> f64 {
        0.1 + bytes * 1e-7
    }
    fn comm_overhead_ms(&self) -> f64 {
        0.07
    }
}

#[test]
fn prop_chunked_sim_degenerates_to_whole_tensor() {
    // DESIGN.md §13 degenerate-case contract: a ChunkSpec with count 1
    // is canonically "no chunking" — the simulator must produce a
    // BIT-identical SimResult, an identical trace, and the same
    // fingerprint as the graph without any descriptor at all.
    check("chunked-degenerate", PropConfig { cases: 64, seed: 0xC4C41 }, |rng| {
        let mut g = random_graph_elems(rng, 8192);
        random_rewrites(&mut g, rng, 6);
        let mut one = g.clone();
        for id in one.allreduces() {
            one.nodes[id].chunk = Some(disco::graph::ChunkSpec::new(1));
        }
        prop_assert!(!one.has_chunking(), "count=1 spec counted as active chunking");
        prop_assert!(
            g.fingerprint() == one.fingerprint(),
            "inactive chunk spec changed the fingerprint"
        );
        let opts = SimOptions {
            straggler_ms: if rng.gen_bool(0.3) { 0.25 } else { 0.0 },
            ignore_comm: rng.gen_bool(0.2),
        };
        let (ra, ta) = disco::sim::trace::capture(&g, &Unit, opts);
        let (rb, tb) = disco::sim::trace::capture(&one, &Unit, opts);
        prop_assert!(ra == rb, "count=1 sim diverged: {ra:?} vs {rb:?}");
        prop_assert!(ta.len() == tb.len(), "trace lengths differ: {} vs {}", ta.len(), tb.len());
        for (x, y) in ta.iter().zip(&tb) {
            prop_assert!(
                x.name == y.name
                    && x.start_ms == y.start_ms
                    && x.end_ms == y.end_ms
                    && x.comm == y.comm
                    && x.chunk == y.chunk,
                "trace event diverged: {x:?} vs {y:?}"
            );
        }
        CaseResult::Pass
    });
}

#[test]
fn prop_chunk_bytes_conserved_and_legal() {
    // Every chunking the vocabulary can produce splits the gradient
    // tensor EXACTLY: per-chunk bytes sum to bytes_out with zero float
    // drift, every chunk respects the MIN_CHUNK_BYTES floor, and counts
    // stay within [2, MAX_CHUNKS].
    check("chunk-conservation", PropConfig { cases: 96, seed: 0xC4C42 }, |rng| {
        let mut g = random_graph_elems(rng, 8192);
        random_rewrites(&mut g, rng, 6);
        if random_chunkings(&mut g, rng, 6) == 0 {
            return CaseResult::Discard;
        }
        prop_assert!(g.validate().is_ok(), "chunking broke the graph");
        for n in g.live() {
            let k = n.chunk_count();
            if k < 2 {
                continue;
            }
            prop_assert!(n.kind == OpKind::AllReduce, "chunk spec on non-AllReduce {}", n.name);
            prop_assert!(k <= fusion::MAX_CHUNKS, "count {k} above MAX_CHUNKS");
            let parts = n.chunk.unwrap().chunk_bytes(n.bytes_out);
            prop_assert!(parts.len() == k as usize, "expected {k} chunks, got {}", parts.len());
            let sum: f64 = parts.iter().sum();
            prop_assert!(
                sum == n.bytes_out,
                "chunk bytes drifted: {} vs {} on {}",
                sum,
                n.bytes_out,
                n.name
            );
            for &p in &parts {
                prop_assert!(
                    p >= fusion::MIN_CHUNK_BYTES,
                    "chunk of {p} bytes below floor on {}",
                    n.name
                );
            }
        }
        CaseResult::Pass
    });
}

#[test]
fn prop_chunk_stream_tiles_the_collective() {
    // Start/wait co-scheduling contract at the trace level: a chunked
    // AllReduce's chunk events tile its channel span contiguously —
    // chunk 1's CommStart is exactly one per-collective overhead after
    // the collective's CommStart (overhead charged ONCE, not per chunk),
    // each chunk's CommWait is its land time, and the last land IS the
    // collective's completion.
    check("chunk-tiling", PropConfig { cases: 64, seed: 0xC4C43 }, |rng| {
        let mut g = random_graph_elems(rng, 8192);
        random_rewrites(&mut g, rng, 4);
        if random_chunkings(&mut g, rng, 5) == 0 {
            return CaseResult::Discard;
        }
        let (_r, tr) = disco::sim::trace::capture(&g, &Ovh, SimOptions::default());
        for n in g.live().filter(|n| n.chunk_count() >= 2) {
            let k = n.chunk_count();
            let Some(whole) = tr.iter().find(|e| e.comm && e.chunk.is_none() && e.name == n.name)
            else {
                return CaseResult::Fail(format!("no collective span for {}", n.name));
            };
            let prefix = format!("{}[", n.name);
            let chunks: Vec<_> = tr
                .iter()
                .filter(|e| e.chunk.is_some() && e.name.starts_with(&prefix))
                .collect();
            prop_assert!(
                chunks.len() == k as usize,
                "{}: {} chunk events for count {k}",
                n.name,
                chunks.len()
            );
            for (i, c) in chunks.iter().enumerate() {
                prop_assert!(
                    c.chunk == Some((i as u32 + 1, k)),
                    "{}: chunk indices out of order",
                    n.name
                );
                prop_assert!(c.end_ms >= c.start_ms, "negative-span chunk on {}", n.name);
            }
            // Overhead once: chunk 1 starts exactly overhead after the
            // collective (Ovh's 0.07 ms, clamped to the transfer).
            let want_first = whole.start_ms + 0.07f64.min(whole.end_ms - whole.start_ms);
            prop_assert!(
                chunks[0].start_ms == want_first,
                "{}: first chunk starts {} not {}",
                n.name,
                chunks[0].start_ms,
                want_first
            );
            for w in chunks.windows(2) {
                prop_assert!(
                    w[0].end_ms == w[1].start_ms,
                    "{}: chunk stream not contiguous",
                    n.name
                );
            }
            prop_assert!(
                chunks[k as usize - 1].end_ms == whole.end_ms,
                "{}: last chunk lands at {} but collective completes at {}",
                n.name,
                chunks[k as usize - 1].end_ms,
                whole.end_ms
            );
        }
        CaseResult::Pass
    });
}

#[test]
fn prop_chunked_never_slower_than_whole_tensor() {
    // EXACT monotonicity on the flat in-order channel: the dual-track
    // clamp guarantees a chunked graph's makespan is never worse than
    // the same graph with every chunk descriptor stripped — no epsilon.
    check("chunk-monotone", PropConfig { cases: 96, seed: 0xC4C44 }, |rng| {
        let mut g = random_graph_elems(rng, 8192);
        random_rewrites(&mut g, rng, 6);
        if random_chunkings(&mut g, rng, 6) == 0 {
            return CaseResult::Discard;
        }
        let mut flat = g.clone();
        for id in flat.allreduces() {
            flat.nodes[id].chunk = None;
        }
        let opts = SimOptions {
            straggler_ms: if rng.gen_bool(0.3) { 0.25 } else { 0.0 },
            ignore_comm: rng.gen_bool(0.2),
        };
        let chunked = simulate(&g, &Ovh, opts);
        let whole = simulate(&flat, &Ovh, opts);
        prop_assert!(
            chunked.makespan_ms <= whole.makespan_ms,
            "chunking made it slower: {} vs {}",
            chunked.makespan_ms,
            whole.makespan_ms
        );
        CaseResult::Pass
    });
}

#[test]
fn prop_shard_canonical_allreduce_is_ddp() {
    // DESIGN.md §16 degenerate-case contract: a ShardSpec with kind
    // AllReduce is canonically "not sharded" — the simulator must
    // produce a BIT-identical SimResult and trace, and the graph must
    // serialize and fingerprint identically to one with no descriptor
    // at all, so every pre-sharding plan key stays warm.
    check("shard-canonical-none", PropConfig { cases: 64, seed: 0x5AD1 }, |rng| {
        let mut g = random_graph(rng);
        random_rewrites(&mut g, rng, 6);
        let mut canon = g.clone();
        for id in canon.allreduces() {
            canon.nodes[id].shard = Some(ShardSpec::new(CollectiveKind::AllReduce));
        }
        prop_assert!(!canon.has_sharding(), "kind=AllReduce spec counted as active sharding");
        prop_assert!(
            g.fingerprint() == canon.fingerprint(),
            "inactive shard spec changed the arena fingerprint"
        );
        let a = disco::service::graph_fingerprint(&g).unwrap();
        let b = disco::service::graph_fingerprint(&canon).unwrap();
        prop_assert!(a == b, "inactive shard spec changed the canonical fingerprint");
        prop_assert!(
            g.to_json() == canon.to_json(),
            "inactive shard spec leaked into serialization"
        );
        let opts = SimOptions {
            straggler_ms: if rng.gen_bool(0.3) { 0.25 } else { 0.0 },
            ignore_comm: rng.gen_bool(0.2),
        };
        let (ra, ta) = disco::sim::trace::capture(&g, &Ovh, opts);
        let (rb, tb) = disco::sim::trace::capture(&canon, &Ovh, opts);
        prop_assert!(ra == rb, "canonical-kind sim diverged: {ra:?} vs {rb:?}");
        prop_assert!(ta.len() == tb.len(), "trace lengths differ: {} vs {}", ta.len(), tb.len());
        for (x, y) in ta.iter().zip(&tb) {
            prop_assert!(
                x.name == y.name
                    && x.start_ms == y.start_ms
                    && x.end_ms == y.end_ms
                    && x.comm == y.comm
                    && x.chunk == y.chunk,
                "trace event diverged: {x:?} vs {y:?}"
            );
        }
        CaseResult::Pass
    });
}

#[test]
fn prop_shard_bytes_conserved_and_legal() {
    // Every sharding the vocabulary can produce is legal (all consumers
    // are optimizer updates, chunking reset, ≥ 2 workers) and splits the
    // gradient tensor EXACTLY: the per-rank reduce-scatter shards sum to
    // bytes_out with zero float drift (so the all-gather re-replicates
    // exactly what was scattered), and no two shards differ by more
    // than one byte.
    check("shard-conservation", PropConfig { cases: 96, seed: 0x5AD2 }, |rng| {
        let mut g = random_graph(rng);
        random_rewrites(&mut g, rng, 6);
        if random_shardings(&mut g, rng, 6) == 0 {
            return CaseResult::Discard;
        }
        prop_assert!(g.validate().is_ok(), "sharding broke the graph");
        prop_assert!(g.num_workers >= 2, "sharded a single-replica graph");
        for n in g.live().filter(|n| n.is_sharded_collective()) {
            prop_assert!(n.kind == OpKind::AllReduce, "shard spec on non-AllReduce {}", n.name);
            prop_assert!(n.chunk.is_none(), "sharded collective {} kept a chunk spec", n.name);
            for c in g.live().filter(|c| c.inputs.contains(&n.id)) {
                prop_assert!(
                    c.role == Role::Optimizer,
                    "non-optimizer consumer {} reads sharded {}",
                    c.name,
                    n.name
                );
            }
            let shards = ShardSpec::shard_bytes(n.bytes_out, g.num_workers);
            prop_assert!(
                shards.len() == g.num_workers,
                "{} shards for {} workers on {}",
                shards.len(),
                g.num_workers,
                n.name
            );
            let sum: f64 = shards.iter().sum();
            prop_assert!(
                sum == n.bytes_out,
                "shard bytes drifted: {} vs {} on {}",
                sum,
                n.bytes_out,
                n.name
            );
            let mx = shards.iter().cloned().fold(0.0f64, f64::max);
            let mn = shards.iter().cloned().fold(f64::INFINITY, f64::min);
            prop_assert!(mx - mn <= 1.0, "shards unbalanced by {} bytes on {}", mx - mn, n.name);
        }
        CaseResult::Pass
    });
}

#[test]
fn prop_sim_workspace_reuse_identical() {
    // One workspace reused across every case and graph size must produce
    // results bit-identical to fresh-allocation runs (SimResult derives
    // PartialEq over raw f64s — no tolerance).
    let mut ws = SimWorkspace::new();
    check("sim-workspace-reuse", PropConfig { cases: 96, seed: 0x5EED }, move |rng| {
        let mut g = random_graph(rng);
        random_rewrites(&mut g, rng, 6);
        let opts = SimOptions {
            straggler_ms: if rng.gen_bool(0.3) { 0.25 } else { 0.0 },
            ignore_comm: rng.gen_bool(0.2),
        };
        let fresh = simulate(&g, &Unit, opts);
        let reused = simulate_in(&g, &Unit, opts, &mut NoRecord, &mut ws);
        prop_assert!(fresh == reused, "workspace reuse diverged: {fresh:?} vs {reused:?}");
        CaseResult::Pass
    });
}

/// Apply a random mutation sequence through a [`CandidateSet`] the way
/// the search does, collecting the delta simulator's mutation frontier.
/// Returns the number of rewrites applied.
fn random_tracked_rewrites(
    g: &mut TrainingGraph,
    rng: &mut Rng,
    tries: usize,
    frontier: &mut Vec<NodeId>,
) -> usize {
    let mut cset = CandidateSet::build(g);
    let mut applied = 0;
    for _ in 0..tries {
        if rng.gen_bool(0.6) {
            let Some(&(p, s)) = rng.choose(cset.op_pairs()) else { continue };
            let kind = if rng.gen_bool(0.5) {
                FusionKind::NonDuplicate
            } else {
                FusionKind::Duplicate
            };
            if let Ok(fx) = cset.apply_op_fusion(g, p, s, kind) {
                frontier.push(p);
                frontier.push(s);
                fx.extend_frontier(g, frontier);
                applied += 1;
            }
        } else {
            let Some(&a) = rng.choose(cset.allreduces()) else { continue };
            let nbrs = fusion::ar_neighbors(g, a);
            let Some(&b) = rng.choose(&nbrs) else { continue };
            if let Ok(fx) = cset.apply_ar_fusion(g, a, b) {
                frontier.push(a);
                frontier.push(b);
                fx.extend_frontier(g, frontier);
                applied += 1;
            }
        }
    }
    applied
}

/// [`random_tracked_rewrites`] with the chunking method mixed in — the
/// full mutation vocabulary the chunking-enabled search draws from.
fn random_tracked_rewrites_chunked(
    g: &mut TrainingGraph,
    rng: &mut Rng,
    tries: usize,
    frontier: &mut Vec<NodeId>,
) -> usize {
    let mut cset = CandidateSet::build(g);
    let mut applied = 0;
    for _ in 0..tries {
        match rng.gen_range(10) {
            0..=4 => {
                let Some(&(p, s)) = rng.choose(cset.op_pairs()) else { continue };
                let kind = if rng.gen_bool(0.5) {
                    FusionKind::NonDuplicate
                } else {
                    FusionKind::Duplicate
                };
                if let Ok(fx) = cset.apply_op_fusion(g, p, s, kind) {
                    frontier.push(p);
                    frontier.push(s);
                    fx.extend_frontier(g, frontier);
                    applied += 1;
                }
            }
            5..=7 => {
                let Some(&a) = rng.choose(cset.allreduces()) else { continue };
                let nbrs = fusion::ar_neighbors(g, a);
                let Some(&b) = rng.choose(&nbrs) else { continue };
                if let Ok(fx) = cset.apply_ar_fusion(g, a, b) {
                    frontier.push(a);
                    frontier.push(b);
                    fx.extend_frontier(g, frontier);
                    applied += 1;
                }
            }
            _ => {
                let Some(&a) = rng.choose(cset.allreduces()) else { continue };
                let counts = fusion::chunk_candidates(g, a, fusion::MAX_CHUNKS);
                let Some(&c) = rng.choose(&counts) else { continue };
                if let Ok(fx) = cset.apply_chunking(g, a, c) {
                    frontier.push(a);
                    fx.extend_frontier(g, frontier);
                    applied += 1;
                }
            }
        }
    }
    applied
}

/// [`random_tracked_rewrites_chunked`] with the sharding method mixed in
/// — the full mutation vocabulary the sharding-enabled search draws from
/// (SetSharding can also *un*-shard, and activating it resets chunking,
/// so the mix exercises every chunk×shard transition).
fn random_tracked_rewrites_sharded(
    g: &mut TrainingGraph,
    rng: &mut Rng,
    tries: usize,
    frontier: &mut Vec<NodeId>,
) -> usize {
    let mut cset = CandidateSet::build(g);
    let mut applied = 0;
    for _ in 0..tries {
        match rng.gen_range(12) {
            0..=4 => {
                let Some(&(p, s)) = rng.choose(cset.op_pairs()) else { continue };
                let kind = if rng.gen_bool(0.5) {
                    FusionKind::NonDuplicate
                } else {
                    FusionKind::Duplicate
                };
                if let Ok(fx) = cset.apply_op_fusion(g, p, s, kind) {
                    frontier.push(p);
                    frontier.push(s);
                    fx.extend_frontier(g, frontier);
                    applied += 1;
                }
            }
            5..=6 => {
                let Some(&a) = rng.choose(cset.allreduces()) else { continue };
                let nbrs = fusion::ar_neighbors(g, a);
                let Some(&b) = rng.choose(&nbrs) else { continue };
                if let Ok(fx) = cset.apply_ar_fusion(g, a, b) {
                    frontier.push(a);
                    frontier.push(b);
                    fx.extend_frontier(g, frontier);
                    applied += 1;
                }
            }
            7..=8 => {
                let Some(&a) = rng.choose(cset.allreduces()) else { continue };
                let counts = fusion::chunk_candidates(g, a, fusion::MAX_CHUNKS);
                let Some(&c) = rng.choose(&counts) else { continue };
                if let Ok(fx) = cset.apply_chunking(g, a, c) {
                    frontier.push(a);
                    fx.extend_frontier(g, frontier);
                    applied += 1;
                }
            }
            _ => {
                let Some(&a) = rng.choose(cset.allreduces()) else { continue };
                let kinds = fusion::shard_candidates(g, a);
                let Some(&k) = rng.choose(&kinds) else { continue };
                if let Ok(fx) = cset.apply_sharding(g, a, k) {
                    frontier.push(a);
                    fx.extend_frontier(g, frontier);
                    applied += 1;
                }
            }
        }
    }
    applied
}

#[test]
fn prop_cost_table_matches_dyn_lookup() {
    // Every live node's table entry must be bitwise equal to the dyn
    // lookup, and table-driven simulation bit-identical to the dyn loop.
    check("cost-table-vs-dyn", PropConfig { cases: 64, seed: 0x7AB1E }, |rng| {
        let device = DeviceModel::gtx1080ti();
        let cluster = Cluster::cluster_a();
        let mut g = random_graph(rng);
        let prof = disco::profiler::profile(&g, &device, &cluster, 1, 5);
        random_rewrites(&mut g, rng, 8);
        let est = CostEstimator::oracle(&prof, &device);
        let table = CostTable::build(&g, &est);
        for n in g.live() {
            match n.kind {
                OpKind::AllReduce => {
                    let want = est.comm_time_ms(n.bytes_out);
                    prop_assert!(
                        table.comm_ms(n.id) == want,
                        "comm table diverged at {}: {} vs {want}",
                        n.id,
                        table.comm_ms(n.id)
                    );
                }
                OpKind::Parameter | OpKind::Constant => {}
                _ => {
                    let want = est.compute_time_ms(n);
                    prop_assert!(
                        table.compute_ms(n.id) == want,
                        "compute table diverged at {}: {} vs {want}",
                        n.id,
                        table.compute_ms(n.id)
                    );
                }
            }
        }
        let opts = SimOptions {
            straggler_ms: if rng.gen_bool(0.3) { 0.25 } else { 0.0 },
            ignore_comm: rng.gen_bool(0.2),
        };
        let dynr = simulate(&g, &est, opts);
        let tabr = simulate_table_in(&g, &table, opts, &mut NoRecord, &mut SimWorkspace::new());
        prop_assert!(dynr == tabr, "table sim diverged: {dynr:?} vs {tabr:?}");
        CaseResult::Pass
    });
}

#[test]
fn prop_delta_sim_matches_full() {
    // The tentpole contract: restoring a parent checkpoint and replaying
    // only the mutation-affected suffix must be BIT-IDENTICAL to a full
    // simulation of the child — across random graphs, random mutation
    // sequences, the SimOptions matrix and every checkpoint cadence.
    check("delta-sim-vs-full", PropConfig { cases: 96, seed: 0xDE17A5 }, |rng| {
        let device = DeviceModel::gtx1080ti();
        let cluster = Cluster::cluster_a();
        let mut parent = random_graph(rng);
        let prof = disco::profiler::profile(&parent, &device, &cluster, 1, 5);
        // Parents deep in the search tree are themselves mutated.
        let parent_muts = rng.gen_range_inclusive(0, 4);
        random_rewrites(&mut parent, rng, parent_muts);
        let mut child = parent.clone();
        let mut frontier: Vec<NodeId> = Vec::new();
        let tries = rng.gen_range_inclusive(1, 6);
        if random_tracked_rewrites(&mut child, rng, tries, &mut frontier) == 0 {
            return CaseResult::Discard;
        }
        let est = CostEstimator::oracle(&prof, &device);
        let opts = SimOptions {
            straggler_ms: if rng.gen_bool(0.4) { 0.3 } else { 0.0 },
            ignore_comm: rng.gen_bool(0.25),
        };
        let every = match rng.gen_range(4) {
            0 => 1,
            1 => rng.gen_range_inclusive(2, 9),
            2 => 0, // auto
            _ => 10_000,
        };
        let mut ws = SimWorkspace::new();
        let parent_table = CostTable::build(&parent, &est);
        let mut log = CheckpointLog::new();
        let _ = simulate_ckpt_in(
            &parent,
            &parent_table,
            opts,
            &mut NoRecord,
            &mut ws,
            &mut log,
            every,
        );
        let mut child_table = CostTable::new();
        child_table.extend_in(&parent_table, &child, &est);
        let delta = simulate_delta(
            &parent,
            &log,
            &child,
            &frontier,
            &child_table,
            opts,
            &mut NoRecord,
            &mut ws,
        );
        let full =
            simulate_table_in(&child, &child_table, opts, &mut NoRecord, &mut SimWorkspace::new());
        prop_assert!(
            delta == full,
            "delta sim diverged (every={every}, opts={opts:?}): {delta:?} vs {full:?}"
        );
        CaseResult::Pass
    });
}

#[test]
fn prop_chunked_delta_sim_matches_full() {
    // The tentpole contract extended to chunked frontiers: with
    // SetChunks in the mutation mix (and possibly-chunked parents), a
    // checkpoint restore + suffix replay must stay BIT-IDENTICAL to a
    // full child simulation — across chunked->chunked,
    // chunked->unchunked and unchunked->chunked parent/child pairs.
    check("delta-sim-vs-full-chunked", PropConfig { cases: 96, seed: 0xDE17C }, |rng| {
        let device = DeviceModel::gtx1080ti();
        let cluster = Cluster::cluster_a();
        let mut parent = random_graph_elems(rng, 8192);
        let prof = disco::profiler::profile(&parent, &device, &cluster, 1, 5);
        let parent_muts = rng.gen_range_inclusive(0, 4);
        random_rewrites(&mut parent, rng, parent_muts);
        if rng.gen_bool(0.5) {
            random_chunkings(&mut parent, rng, 3);
        }
        let mut child = parent.clone();
        let mut frontier: Vec<NodeId> = Vec::new();
        let tries = rng.gen_range_inclusive(1, 6);
        if random_tracked_rewrites_chunked(&mut child, rng, tries, &mut frontier) == 0 {
            return CaseResult::Discard;
        }
        let est = CostEstimator::oracle(&prof, &device);
        let opts = SimOptions {
            straggler_ms: if rng.gen_bool(0.4) { 0.3 } else { 0.0 },
            ignore_comm: rng.gen_bool(0.25),
        };
        let every = match rng.gen_range(4) {
            0 => 1,
            1 => rng.gen_range_inclusive(2, 9),
            2 => 0, // auto
            _ => 10_000,
        };
        let mut ws = SimWorkspace::new();
        let parent_table = CostTable::build(&parent, &est);
        let mut log = CheckpointLog::new();
        let _ = simulate_ckpt_in(
            &parent,
            &parent_table,
            opts,
            &mut NoRecord,
            &mut ws,
            &mut log,
            every,
        );
        let mut child_table = CostTable::new();
        child_table.extend_in(&parent_table, &child, &est);
        let delta = simulate_delta(
            &parent,
            &log,
            &child,
            &frontier,
            &child_table,
            opts,
            &mut NoRecord,
            &mut ws,
        );
        let full =
            simulate_table_in(&child, &child_table, opts, &mut NoRecord, &mut SimWorkspace::new());
        prop_assert!(
            delta == full,
            "chunked delta sim diverged (every={every}, opts={opts:?}): {delta:?} vs {full:?}"
        );
        CaseResult::Pass
    });
}

#[test]
fn prop_sharded_delta_sim_matches_full() {
    // The tentpole contract extended to sharded frontiers: with
    // SetSharding in the mutation mix (and possibly-sharded or chunked
    // parents), a checkpoint restore + suffix replay must stay
    // BIT-IDENTICAL to a full child simulation — across DDP->sharded,
    // sharded->DDP, sharded->more-sharded and mixed chunk+shard
    // parent/child pairs.
    check("delta-sim-vs-full-sharded", PropConfig { cases: 96, seed: 0x5AD3 }, |rng| {
        let device = DeviceModel::gtx1080ti();
        let cluster = Cluster::cluster_a();
        let mut parent = random_graph_elems(rng, 8192);
        let prof = disco::profiler::profile(&parent, &device, &cluster, 1, 5);
        let parent_muts = rng.gen_range_inclusive(0, 4);
        random_rewrites(&mut parent, rng, parent_muts);
        if rng.gen_bool(0.5) {
            random_shardings(&mut parent, rng, 2);
        } else if rng.gen_bool(0.5) {
            random_chunkings(&mut parent, rng, 2);
        }
        let mut child = parent.clone();
        let mut frontier: Vec<NodeId> = Vec::new();
        let tries = rng.gen_range_inclusive(1, 6);
        if random_tracked_rewrites_sharded(&mut child, rng, tries, &mut frontier) == 0 {
            return CaseResult::Discard;
        }
        let est = CostEstimator::oracle(&prof, &device);
        let opts = SimOptions {
            straggler_ms: if rng.gen_bool(0.4) { 0.3 } else { 0.0 },
            ignore_comm: rng.gen_bool(0.25),
        };
        let every = match rng.gen_range(4) {
            0 => 1,
            1 => rng.gen_range_inclusive(2, 9),
            2 => 0, // auto
            _ => 10_000,
        };
        let mut ws = SimWorkspace::new();
        let parent_table = CostTable::build(&parent, &est);
        let mut log = CheckpointLog::new();
        let _ = simulate_ckpt_in(
            &parent,
            &parent_table,
            opts,
            &mut NoRecord,
            &mut ws,
            &mut log,
            every,
        );
        let mut child_table = CostTable::new();
        child_table.extend_in(&parent_table, &child, &est);
        let delta = simulate_delta(
            &parent,
            &log,
            &child,
            &frontier,
            &child_table,
            opts,
            &mut NoRecord,
            &mut ws,
        );
        let full =
            simulate_table_in(&child, &child_table, opts, &mut NoRecord, &mut SimWorkspace::new());
        prop_assert!(
            delta == full,
            "sharded delta sim diverged (every={every}, opts={opts:?}): {delta:?} vs {full:?}"
        );
        CaseResult::Pass
    });
}

#[test]
fn prop_pre_sharding_records_replay_unsharded() {
    // Store-compat contract for record v4: records written before the
    // sharding vocabulary existed (v1-v3) must load under the bumped
    // version and replay to their exact recorded winner — necessarily
    // unsharded (no "sh" tags predate v4) and with zero simulator
    // calls: try_replay_hit replays mutations only and takes no cost
    // source at all.
    use disco::util::json::Json;
    check("store-downgrade-replay", PropConfig { cases: 6, seed: 0x5AD4 }, |rng| {
        let device = DeviceModel::gtx1080ti();
        let cluster = Cluster::cluster_a();
        let g = random_graph(rng);
        let prof = disco::profiler::profile(&g, &device, &cluster, 1, 5);
        let est = CostEstimator::oracle(&prof, &device);
        let cfg = SearchConfig {
            unchanged_limit: 30,
            max_queue: 32,
            seed: rng.next_u64(),
            eval_threads: 1,
            track_best_path: true,
            ..Default::default()
        };
        let r = backtracking_search(&g, &est, &cfg);
        let gfp = disco::service::graph_fingerprint(&g).unwrap();
        let rec = disco::service::PlanRecord {
            key: "k".to_string(),
            graph_fp: gfp.hex(),
            arena_fp: disco::service::arena_fingerprint(&g),
            model: g.name.clone(),
            sketch: disco::service::GraphSketch::of(&g),
            muts: r.best_path.clone(),
            best_cost_ms: r.best_cost_ms,
            initial_cost_ms: r.initial_cost_ms,
            evals: r.evals,
            steps: r.steps,
            elapsed_ms: 1.0,
        };
        for old in [1.0, 2.0, 3.0] {
            let mut j = rec.to_json();
            if let Json::Obj(m) = &mut j {
                m.insert("v".into(), Json::Num(old));
            }
            let back = match disco::service::PlanRecord::from_json(&j) {
                Some(b) => b,
                None => return CaseResult::Fail(format!("v{old} record rejected under v4")),
            };
            prop_assert!(
                !back.muts.iter().any(|m| matches!(m, Mutation::SetSharding { .. })),
                "pre-sharding record decoded a SetSharding mutation"
            );
            let replayed = match disco::service::try_replay_hit(&back, &g) {
                Some(p) => p,
                None => return CaseResult::Fail(format!("v{old} record did not replay")),
            };
            prop_assert!(!replayed.has_sharding(), "downgrade replay produced a sharded plan");
            prop_assert!(
                replayed.fingerprint() == r.best.fingerprint(),
                "downgrade replay does not reproduce the recorded winner"
            );
        }
        CaseResult::Pass
    });
}

#[test]
fn prop_search_delta_sim_matches_full() {
    // The delta_sim / cost_table engine toggles must never change the
    // search trajectory for a seed.
    check("search-deltasim-vs-full", PropConfig { cases: 8, seed: 0xC0517 }, |rng| {
        let device = DeviceModel::gtx1080ti();
        let cluster = Cluster::cluster_a();
        let g = random_graph(rng);
        let prof = disco::profiler::profile(&g, &device, &cluster, 1, 5);
        let est = CostEstimator::oracle(&prof, &device);
        let base = SearchConfig {
            unchanged_limit: 30,
            max_queue: 32,
            seed: rng.next_u64(),
            eval_threads: 1,
            ckpt_every: rng.gen_range_inclusive(0, 16),
            ..Default::default()
        };
        let delta = backtracking_search(&g, &est, &base);
        let full_cfg = SearchConfig { delta_sim: false, cost_table: false, ..base };
        let full = backtracking_search(&g, &est, &full_cfg);
        prop_assert!(
            delta.best_cost_ms == full.best_cost_ms
                && delta.evals == full.evals
                && delta.steps == full.steps,
            "trajectory diverged: {}ms/{} vs {}ms/{}",
            delta.best_cost_ms,
            delta.evals,
            full.best_cost_ms,
            full.evals
        );
        prop_assert!(
            delta.best.fingerprint() == full.best.fingerprint(),
            "best modules differ"
        );
        CaseResult::Pass
    });
}

#[test]
fn prop_search_delta_matches_eager() {
    // Delta-rematerialized candidates must drive the search to the exact
    // same trajectory as eager full-graph clones.
    check("search-delta-vs-eager", PropConfig { cases: 10, seed: 0xDE17A }, |rng| {
        let device = DeviceModel::gtx1080ti();
        let cluster = Cluster::cluster_a();
        let g = random_graph(rng);
        let prof = disco::profiler::profile(&g, &device, &cluster, 1, 5);
        let est = CostEstimator::oracle(&prof, &device);
        let base = SearchConfig {
            unchanged_limit: 30,
            max_queue: 32,
            seed: rng.next_u64(),
            eval_threads: 1,
            ..Default::default()
        };
        let delta = backtracking_search(&g, &est, &base);
        let eager_cfg = SearchConfig { delta_candidates: false, ..base };
        let eager = backtracking_search(&g, &est, &eager_cfg);
        prop_assert!(
            delta.best_cost_ms == eager.best_cost_ms && delta.evals == eager.evals,
            "trajectory diverged: {}ms/{} vs {}ms/{}",
            delta.best_cost_ms,
            delta.evals,
            eager.best_cost_ms,
            eager.evals
        );
        prop_assert!(
            delta.best.fingerprint() == eager.best.fingerprint(),
            "best modules differ"
        );
        CaseResult::Pass
    });
}

#[test]
fn prop_search_parallel_matches_serial() {
    // Fixed seed: worker-thread evaluation must reproduce the serial
    // search exactly (mutations are generated serially; merge order is
    // method order).
    check("search-parallel-vs-serial", PropConfig { cases: 8, seed: 0x9A7 }, |rng| {
        let device = DeviceModel::gtx1080ti();
        let cluster = Cluster::cluster_a();
        let g = random_graph(rng);
        let prof = disco::profiler::profile(&g, &device, &cluster, 1, 3);
        let est = CostEstimator::oracle(&prof, &device);
        let base = SearchConfig {
            unchanged_limit: 30,
            max_queue: 32,
            seed: rng.next_u64(),
            eval_threads: 1,
            ..Default::default()
        };
        let serial = backtracking_search(&g, &est, &base);
        // parallel_min_nodes: 0 forces the worker path on small graphs.
        let par_cfg = SearchConfig { eval_threads: 3, parallel_min_nodes: 0, ..base };
        let parallel = backtracking_search(&g, &est, &par_cfg);
        prop_assert!(
            serial.best_cost_ms == parallel.best_cost_ms
                && serial.evals == parallel.evals
                && serial.steps == parallel.steps,
            "parallel diverged: {}ms/{} vs {}ms/{}",
            serial.best_cost_ms,
            serial.evals,
            parallel.best_cost_ms,
            parallel.evals
        );
        prop_assert!(
            serial.best.fingerprint() == parallel.best.fingerprint(),
            "best modules differ"
        );
        CaseResult::Pass
    });
}

#[test]
fn prop_estimator_cache_consistent() {
    // Cached and uncached evaluation of the same graph agree.
    check("estimator-cache", PropConfig { cases: 32, seed: 0xD0D }, |rng| {
        let device = DeviceModel::gtx1080ti();
        let cluster = Cluster::cluster_a();
        let mut g = random_graph(rng);
        let prof = disco::profiler::profile(&g, &device, &cluster, 1, 5);
        random_rewrites(&mut g, rng, 8);
        let est = CostEstimator::oracle(&prof, &device);
        let a = simulate(&g, &est, SimOptions::default()).makespan_ms;
        est.warm_cache(&g);
        let b = simulate(&g, &est, SimOptions::default()).makespan_ms;
        prop_assert!((a - b).abs() < 1e-9, "cache changed cost: {a} vs {b}");
        CaseResult::Pass
    });
}

#[test]
fn prop_allreduce_equals_local_average() {
    check("collective-average", PropConfig { cases: 24, seed: 0xE0E }, |rng| {
        let world = rng.gen_range_inclusive(1, 6);
        let len = rng.gen_range_inclusive(1, 300);
        let inputs: Vec<Vec<f32>> = (0..world)
            .map(|w| {
                let mut r = Rng::new(rng.next_u64() ^ w as u64);
                (0..len).map(|_| (r.gen_f64() * 4.0 - 2.0) as f32).collect()
            })
            .collect();
        let mut expect = vec![0.0f32; len];
        for inp in &inputs {
            for (e, x) in expect.iter_mut().zip(inp) {
                *e += *x / world as f32;
            }
        }
        let inputs2 = inputs.clone();
        let results = run_workers(world, move |peer| {
            let mut d = inputs2[peer.rank].clone();
            peer.allreduce_mean(&mut d);
            d
        });
        for r in &results {
            for (a, e) in r.iter().zip(&expect) {
                prop_assert!((a - e).abs() < 1e-4, "allreduce mismatch: {a} vs {e}");
            }
        }
        CaseResult::Pass
    });
}

#[test]
fn prop_coordinator_consistent_broadcast() {
    // Every worker acks the same fingerprint the leader computed, for
    // arbitrary (searched or raw) strategies.
    check("coordinator-broadcast", PropConfig { cases: 12, seed: 0xF0F }, |rng| {
        let mut g = random_graph(rng);
        random_rewrites(&mut g, rng, 5);
        let cfg = disco::coordinator::EnactConfig {
            world: rng.gen_range_inclusive(1, 4),
            iterations: 1,
            ..Default::default()
        };
        match disco::coordinator::enact(&g, &cfg) {
            Ok(report) => {
                prop_assert!(report.acks == cfg.world, "acks {} != {}", report.acks, cfg.world);
                prop_assert!(report.per_rank.len() == cfg.world, "missing reports");
                CaseResult::Pass
            }
            Err(e) => CaseResult::Fail(format!("enact failed: {e}")),
        }
    });
}

#[test]
fn prop_serial_roundtrip_lossless() {
    // JSON (de)serialization must preserve EVERYTHING the strategy
    // service's canonical fingerprint hashes — shapes, dtypes, flops,
    // byte traffic, fused-group contents, tombstones, duplicate operand
    // edges, chunk descriptors and shard descriptors — across arbitrary
    // post-fusion (and post-chunking/post-sharding) graph states.
    check("serial-roundtrip", PropConfig { cases: 48, seed: 0x5E41A1 }, |rng| {
        // Half the cases use gradients large enough for the chunking
        // vocabulary to apply, so chunk specs actually ride the wire.
        let elems = if rng.gen_bool(0.5) { 8192 } else { 256 };
        let mut g = random_graph_elems(rng, elems);
        random_rewrites(&mut g, rng, rng.gen_range_inclusive(0, 8));
        random_chunkings(&mut g, rng, rng.gen_range_inclusive(0, 4));
        // ... and shard descriptors (DESIGN.md §16) ride it too.
        random_shardings(&mut g, rng, rng.gen_range_inclusive(0, 3));
        let text = g.to_json();
        let back = match TrainingGraph::from_json(&text) {
            Ok(b) => b,
            Err(e) => return CaseResult::Fail(format!("reparse failed: {e}")),
        };
        prop_assert!(g == back, "round-trip not structurally identical");
        prop_assert!(
            g.fingerprint() == back.fingerprint(),
            "arena fingerprint drifted across serialization"
        );
        let a = disco::service::graph_fingerprint(&g).unwrap();
        let b = disco::service::graph_fingerprint(&back).unwrap();
        prop_assert!(a == b, "canonical fingerprint drifted: {a} vs {b}");
        CaseResult::Pass
    });
}

#[test]
fn prop_track_best_path_is_pure_observation() {
    // The service's path tracking must never steer the search, and the
    // recorded path must replay the input into exactly the winner.
    check("search-best-path", PropConfig { cases: 8, seed: 0xBE57 }, |rng| {
        let device = DeviceModel::gtx1080ti();
        let cluster = Cluster::cluster_a();
        let g = random_graph(rng);
        let prof = disco::profiler::profile(&g, &device, &cluster, 1, 5);
        let est = CostEstimator::oracle(&prof, &device);
        let base = SearchConfig {
            unchanged_limit: 30,
            max_queue: 32,
            seed: rng.next_u64(),
            eval_threads: 1,
            ..Default::default()
        };
        let off = backtracking_search(&g, &est, &base);
        let on_cfg = SearchConfig { track_best_path: true, ..base };
        let on = backtracking_search(&g, &est, &on_cfg);
        prop_assert!(
            off.best_cost_ms == on.best_cost_ms
                && off.evals == on.evals
                && off.steps == on.steps
                && off.best.fingerprint() == on.best.fingerprint(),
            "path tracking changed the trajectory"
        );
        prop_assert!(off.best_path.is_empty(), "path recorded while tracking off");
        let mut replayed = g.clone();
        for m in &on.best_path {
            if let Err(e) = m.replay(&mut replayed) {
                return CaseResult::Fail(format!("best_path replay failed: {e}"));
            }
        }
        prop_assert!(
            replayed.fingerprint() == on.best.fingerprint(),
            "best_path does not reproduce the winner"
        );
        CaseResult::Pass
    });
}

#[test]
fn prop_search_trace_is_pure_observation() {
    // Telemetry must never steer the search: with the toggle off the
    // sink is provably untouched (PanicSink), and with it on the result
    // is bit-identical — the final event's best_ms is the exact
    // best_cost_ms (DESIGN.md §15).
    use disco::search::backtracking_search_traced;
    use disco::util::trace::{MemSink, PanicSink};
    check("search-trace-purity", PropConfig { cases: 6, seed: 0x7A4CE }, |rng| {
        let device = DeviceModel::gtx1080ti();
        let cluster = Cluster::cluster_a();
        let g = random_graph(rng);
        let prof = disco::profiler::profile(&g, &device, &cluster, 1, 5);
        let est = CostEstimator::oracle(&prof, &device);
        let base = SearchConfig {
            unchanged_limit: 30,
            max_queue: 32,
            seed: rng.next_u64(),
            eval_threads: 1,
            ..Default::default()
        };
        // Trace off: a panicking sink proves the disabled path never
        // reaches the sink boundary.
        let off = backtracking_search_traced(&g, &est, &base, &[], &mut PanicSink);
        let on_cfg = SearchConfig { trace: true, ..base };
        let mut sink = MemSink::default();
        let on = backtracking_search_traced(&g, &est, &on_cfg, &[], &mut sink);
        prop_assert!(
            off.best_cost_ms == on.best_cost_ms
                && off.evals == on.evals
                && off.steps == on.steps
                && off.best.fingerprint() == on.best.fingerprint(),
            "tracing changed the trajectory: {}ms/{} vs {}ms/{}",
            off.best_cost_ms,
            off.evals,
            on.best_cost_ms,
            on.evals
        );
        let last = sink.events.last().expect("traced run must emit events");
        prop_assert!(last.name == "final", "last event is {:?}", last.name);
        let best_ms = last.args.iter().find(|(k, _)| *k == "best_ms").unwrap().1;
        prop_assert!(
            best_ms == on.best_cost_ms,
            "final event best_ms {best_ms} != best_cost_ms {}",
            on.best_cost_ms
        );
        CaseResult::Pass
    });
}

#[test]
fn prop_histogram_percentile_error_bounded_by_bucket_width() {
    // For any sample set and quantile, the histogram estimate e and the
    // exact nearest-rank percentile s satisfy s ≤ e < 2s (log₂ buckets:
    // the estimate is the upper bound of the bucket holding the rank).
    use disco::util::metrics::{Histogram, LO};
    check("histogram-percentile-bound", PropConfig { cases: 64, seed: 0x4157 }, |rng| {
        let n = rng.gen_range_inclusive(1, 200);
        // Log-uniform spread across ~40 octaves, all ≥ LO (below the
        // first bucket bound the estimate clamps to LO by design).
        let mut samples: Vec<f64> =
            (0..n).map(|_| LO * (2f64).powf(rng.gen_f64() * 40.0)).collect();
        let h = Histogram::default();
        for &s in &samples {
            h.observe(s);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [1.0, 25.0, 50.0, 90.0, 99.0, 100.0] {
            let rank = ((q / 100.0) * n as f64).ceil().max(1.0) as usize;
            let exact = samples[rank - 1];
            let est = h.percentile(q);
            prop_assert!(
                est >= exact * (1.0 - 1e-9) && est <= exact * 2.0 * (1.0 + 1e-9),
                "q{q}: exact {exact} est {est} outside [s, 2s]"
            );
        }
        prop_assert!(
            (h.sum() - samples.iter().sum::<f64>()).abs() < 1e-6 * h.sum().max(1.0),
            "histogram sum drifted"
        );
        CaseResult::Pass
    });
}

// ---------------------------------------------------------------------------
// Interpreter vs naive reference (DESIGN.md §9): for each new op family,
// random shapes/dimension-numbers executed by the interpreter must match
// a per-element reference implementation written directly from the spec.
// Equality is exact for integer/pred ops and for float ops whose
// reference mirrors the storage contract (compute in f32, round once);
// f16/bf16 compare as storage bit patterns (0 ULPs).
// ---------------------------------------------------------------------------

use disco::runtime::interp::Interp;
use disco::runtime::value::{f16_bits_to_f32, f32_to_f16_bits};
use disco::runtime::{lit_f32, lit_i32, lit_to_f32};

fn rand_f32s(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.gen_normal() * 2.0) as f32).collect()
}

fn run_floats(text: &str, inputs: &[disco::xla_stub::Literal]) -> Result<Vec<f32>, String> {
    let interp = Interp::from_text(text).map_err(|e| format!("parse: {e:#}"))?;
    let out = interp.run(inputs).map_err(|e| format!("run: {e:#}"))?;
    lit_to_f32(&out[0]).map_err(|e| format!("readback: {e:#}"))
}

#[test]
fn prop_interp_matches_reference() {
    check("interp-vs-reference", PropConfig { cases: 120, seed: 0x1417 }, |rng| {
        match rng.gen_range(7) {
            // gather: 1-D lookups and 1-D windows, OOB starts clamp.
            0 => {
                let n = rng.gen_range_inclusive(2, 8);
                let k = rng.gen_range_inclusive(1, 6);
                let w = rng.gen_range_inclusive(1, n);
                let vals = rand_f32s(rng, n);
                let ix: Vec<i32> =
                    (0..k).map(|_| rng.gen_range_inclusive(0, n + 8) as i32 - 4).collect();
                let text = format!(
                    "HloModule g\nENTRY main {{\n  v = f32[{n}] parameter(0)\n  ix = s32[{k},1] parameter(1)\n  ROOT g = f32[{k},{w}] gather(v, ix), offset_dims={{1}}, collapsed_slice_dims={{}}, start_index_map={{0}}, index_vector_dim=1, slice_sizes={{{w}}}\n}}\n"
                );
                let got = match run_floats(
                    &text,
                    &[lit_f32(&vals, &[n]).unwrap(), lit_i32(&ix, &[k, 1]).unwrap()],
                ) {
                    Ok(v) => v,
                    Err(e) => return CaseResult::Fail(e),
                };
                let mut want = Vec::new();
                for &i in &ix {
                    let start = (i as i64).clamp(0, (n - w) as i64) as usize;
                    for o in 0..w {
                        want.push(vals[start + o]);
                    }
                }
                prop_assert!(got == want, "gather n={n} k={k} w={w}: {got:?} vs {want:?}");
            }
            // scatter-add (f32 and s32): duplicates accumulate in update
            // order, out-of-bounds updates are dropped.
            1 => {
                let n = rng.gen_range_inclusive(2, 8);
                let k = rng.gen_range_inclusive(1, 8);
                let ix: Vec<i32> =
                    (0..k).map(|_| rng.gen_range_inclusive(0, n + 8) as i32 - 4).collect();
                if rng.gen_bool(0.5) {
                    let base = rand_f32s(rng, n);
                    let upd = rand_f32s(rng, k);
                    let text = format!(
                        "HloModule s\nadd_f {{\n  a = f32[] parameter(0)\n  b = f32[] parameter(1)\n  ROOT r = f32[] add(a, b)\n}}\nENTRY main {{\n  z = f32[{n}] parameter(0)\n  ix = s32[{k},1] parameter(1)\n  u = f32[{k}] parameter(2)\n  ROOT s = f32[{n}] scatter(z, ix, u), update_window_dims={{}}, inserted_window_dims={{0}}, scatter_dims_to_operand_dims={{0}}, index_vector_dim=1, to_apply=add_f\n}}\n"
                    );
                    let got = match run_floats(
                        &text,
                        &[
                            lit_f32(&base, &[n]).unwrap(),
                            lit_i32(&ix, &[k, 1]).unwrap(),
                            lit_f32(&upd, &[k]).unwrap(),
                        ],
                    ) {
                        Ok(v) => v,
                        Err(e) => return CaseResult::Fail(e),
                    };
                    let mut want = base.clone();
                    for (j, &i) in ix.iter().enumerate() {
                        if i >= 0 && (i as usize) < n {
                            want[i as usize] += upd[j]; // same f32 order as the interpreter
                        }
                    }
                    prop_assert!(got == want, "scatter f32: {got:?} vs {want:?}");
                } else {
                    let base: Vec<i32> = (0..n).map(|_| rng.gen_range(100) as i32 - 50).collect();
                    let upd: Vec<i32> = (0..k).map(|_| rng.gen_range(100) as i32 - 50).collect();
                    let text = format!(
                        "HloModule s\nadd_i {{\n  a = s32[] parameter(0)\n  b = s32[] parameter(1)\n  ROOT r = s32[] add(a, b)\n}}\nENTRY main {{\n  z = s32[{n}] parameter(0)\n  ix = s32[{k},1] parameter(1)\n  u = s32[{k}] parameter(2)\n  ROOT s = s32[{n}] scatter(z, ix, u), update_window_dims={{}}, inserted_window_dims={{0}}, scatter_dims_to_operand_dims={{0}}, index_vector_dim=1, to_apply=add_i\n}}\n"
                    );
                    let interp = Interp::from_text(&text).unwrap();
                    let out = interp
                        .run(&[
                            lit_i32(&base, &[n]).unwrap(),
                            lit_i32(&ix, &[k, 1]).unwrap(),
                            lit_i32(&upd, &[k]).unwrap(),
                        ])
                        .unwrap();
                    let got = out[0].to_vec::<i32>().unwrap();
                    let mut want = base.clone();
                    for (j, &i) in ix.iter().enumerate() {
                        if i >= 0 && (i as usize) < n {
                            want[i as usize] = want[i as usize].wrapping_add(upd[j]);
                        }
                    }
                    prop_assert!(got == want, "scatter s32: {got:?} vs {want:?}");
                }
            }
            // dynamic-slice + dynamic-update-slice: starts clamp.
            2 => {
                let n = rng.gen_range_inclusive(2, 9);
                let w = rng.gen_range_inclusive(1, n);
                let vals = rand_f32s(rng, n);
                let upd = rand_f32s(rng, w);
                let raw = rng.gen_range_inclusive(0, n + 6) as i64 - 3;
                let start = raw.clamp(0, (n - w) as i64) as usize;
                let text = format!(
                    "HloModule d\nENTRY main {{\n  v = f32[{n}] parameter(0)\n  i = s32[] parameter(1)\n  u = f32[{w}] parameter(2)\n  ds = f32[{w}] dynamic-slice(v, i), dynamic_slice_sizes={{{w}}}\n  dus = f32[{n}] dynamic-update-slice(v, u, i)\n  ROOT t = (f32[{w}], f32[{n}]) tuple(ds, dus)\n}}\n"
                );
                let interp = Interp::from_text(&text).unwrap();
                let out = interp
                    .run(&[
                        lit_f32(&vals, &[n]).unwrap(),
                        lit_i32(&[raw as i32], &[]).unwrap(),
                        lit_f32(&upd, &[w]).unwrap(),
                    ])
                    .unwrap();
                let ds = lit_to_f32(&out[0]).unwrap();
                let dus = lit_to_f32(&out[1]).unwrap();
                let want_ds: Vec<f32> = (0..w).map(|o| vals[start + o]).collect();
                let mut want_dus = vals.clone();
                want_dus[start..start + w].copy_from_slice(&upd);
                prop_assert!(ds == want_ds, "dynamic-slice: {ds:?} vs {want_ds:?}");
                prop_assert!(dus == want_dus, "dynamic-update-slice: {dus:?} vs {want_dus:?}");
            }
            // pad (incl. negative low/high and interior) + reverse.
            3 => {
                let n = rng.gen_range_inclusive(1, 7);
                let vals = rand_f32s(rng, n);
                let interior = rng.gen_range(3);
                let span = n as i64 + (n as i64 - 1).max(0) * interior as i64;
                let lo = rng.gen_range_inclusive(0, 4) as i64 - 2;
                let mut hi = rng.gen_range_inclusive(0, 4) as i64 - 2;
                if lo + hi + span < 0 {
                    hi = -span - lo; // keep the result non-negative-sized
                }
                let out_n = (lo + hi + span) as usize;
                let text = format!(
                    "HloModule p\nENTRY main {{\n  v = f32[{n}] parameter(0)\n  c = f32[] constant(9)\n  p = f32[{out_n}] pad(v, c), padding={lo}_{hi}_{interior}\n  r = f32[{n}] reverse(v), dimensions={{0}}\n  ROOT t = (f32[{out_n}], f32[{n}]) tuple(p, r)\n}}\n"
                );
                let interp = Interp::from_text(&text).unwrap();
                let out = interp.run(&[lit_f32(&vals, &[n]).unwrap()]).unwrap();
                let got_p = lit_to_f32(&out[0]).unwrap();
                let got_r = lit_to_f32(&out[1]).unwrap();
                let mut want_p = vec![9.0f32; out_n];
                for (i, &v) in vals.iter().enumerate() {
                    let o = lo + (i as i64) * (interior as i64 + 1);
                    if o >= 0 && (o as usize) < out_n {
                        want_p[o as usize] = v;
                    }
                }
                let want_r: Vec<f32> = vals.iter().rev().copied().collect();
                prop_assert!(
                    got_p == want_p,
                    "pad {lo}_{hi}_{interior} over {n}: {got_p:?} vs {want_p:?}"
                );
                prop_assert!(got_r == want_r, "reverse: {got_r:?} vs {want_r:?}");
            }
            // while: T doublings of a vector, T decided by the condition
            // constant — reference replays the same f32 arithmetic.
            4 => {
                let m = rng.gen_range_inclusive(1, 5);
                let t = rng.gen_range(6);
                let vals = rand_f32s(rng, m);
                let text = format!(
                    "HloModule w\ncond {{\n  c = (s32[], f32[{m}]) parameter(0)\n  i = s32[] get-tuple-element(c), index=0\n  tt = s32[] constant({t})\n  ROOT lt = pred[] compare(i, tt), direction=LT\n}}\nbody {{\n  c = (s32[], f32[{m}]) parameter(0)\n  i = s32[] get-tuple-element(c), index=0\n  v = f32[{m}] get-tuple-element(c), index=1\n  v2 = f32[{m}] add(v, v)\n  one = s32[] constant(1)\n  i2 = s32[] add(i, one)\n  ROOT r = (s32[], f32[{m}]) tuple(i2, v2)\n}}\nENTRY main {{\n  v0 = f32[{m}] parameter(0)\n  z = s32[] constant(0)\n  init = (s32[], f32[{m}]) tuple(z, v0)\n  w = (s32[], f32[{m}]) while(init), condition=cond, body=body\n  ROOT v = f32[{m}] get-tuple-element(w), index=1\n}}\n"
                );
                let got = match run_floats(&text, &[lit_f32(&vals, &[m]).unwrap()]) {
                    Ok(v) => v,
                    Err(e) => return CaseResult::Fail(e),
                };
                let mut want = vals.clone();
                for _ in 0..t {
                    for x in want.iter_mut() {
                        *x += *x;
                    }
                }
                prop_assert!(got == want, "while t={t}: {got:?} vs {want:?}");
            }
            // f16 elementwise: storage-rounding contract — compute in
            // f32 on the narrowed operands, round once; 0 ULPs apart.
            5 => {
                let m = rng.gen_range_inclusive(1, 6);
                let ops = ["add", "subtract", "multiply", "maximum"];
                let op = *rng.choose(&ops).unwrap();
                let a = rand_f32s(rng, m);
                let b = rand_f32s(rng, m);
                let text = format!(
                    "HloModule h\nENTRY main {{\n  a = f16[{m}] parameter(0)\n  b = f16[{m}] parameter(1)\n  ROOT r = f16[{m}] {op}(a, b)\n}}\n"
                );
                let got = match run_floats(
                    &text,
                    &[lit_f32(&a, &[m]).unwrap(), lit_f32(&b, &[m]).unwrap()],
                ) {
                    Ok(v) => v,
                    Err(e) => return CaseResult::Fail(e),
                };
                for i in 0..m {
                    let ah = f16_bits_to_f32(f32_to_f16_bits(a[i]));
                    let bh = f16_bits_to_f32(f32_to_f16_bits(b[i]));
                    let r = match op {
                        "add" => ah + bh,
                        "subtract" => ah - bh,
                        "multiply" => ah * bh,
                        _ => ah.max(bh),
                    };
                    let want_bits = f32_to_f16_bits(r);
                    let got_bits = f32_to_f16_bits(got[i]);
                    prop_assert!(
                        want_bits == got_bits,
                        "f16 {op} [{i}]: {} vs {} ({a:?} {b:?})",
                        got[i],
                        r
                    );
                }
            }
            // integer / pred ops: exact equality against wrapping
            // reference arithmetic.
            _ => {
                let m = rng.gen_range_inclusive(1, 6);
                let a: Vec<i32> = (0..m).map(|_| rng.gen_range(200) as i32 - 100).collect();
                let b: Vec<i32> = (0..m).map(|_| rng.gen_range(200) as i32 - 100).collect();
                let text = format!(
                    "HloModule i\nENTRY main {{\n  a = s32[{m}] parameter(0)\n  b = s32[{m}] parameter(1)\n  s = s32[{m}] add(a, b)\n  p = s32[{m}] multiply(a, b)\n  lt = pred[{m}] compare(a, b), direction=LT\n  sel = s32[{m}] select(lt, a, b)\n  nn = pred[{m}] not(lt)\n  ROOT t = (s32[{m}], s32[{m}], pred[{m}], s32[{m}], pred[{m}]) tuple(s, p, lt, sel, nn)\n}}\n"
                );
                let interp = Interp::from_text(&text).unwrap();
                let out = interp
                    .run(&[lit_i32(&a, &[m]).unwrap(), lit_i32(&b, &[m]).unwrap()])
                    .unwrap();
                let got: Vec<Vec<i32>> =
                    out.iter().map(|l| l.to_vec::<i32>().unwrap()).collect();
                for i in 0..m {
                    prop_assert!(got[0][i] == a[i].wrapping_add(b[i]), "add mismatch");
                    prop_assert!(got[1][i] == a[i].wrapping_mul(b[i]), "mul mismatch");
                    let lt = (a[i] < b[i]) as i32;
                    prop_assert!(got[2][i] == lt, "compare mismatch");
                    prop_assert!(got[3][i] == if lt != 0 { a[i] } else { b[i] }, "select mismatch");
                    prop_assert!(got[4][i] == 1 - lt, "not mismatch");
                }
            }
        }
        CaseResult::Pass
    });
}
