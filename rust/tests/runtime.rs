//! Integration: the Rust PJRT runtime executing the AOT artifacts.
//! Requires `make artifacts` to have run (skips otherwise).

use disco::device::DeviceModel;
use disco::estimator::{AnalyticalFused, FusedOpEstimator};
use disco::graph::{FusedGroup, OpKind, OrigOp};
use disco::network::Cluster;
use disco::profiler;
use disco::runtime::gnn::{GnnPredictor, GnnTrainer};
use disco::runtime::trainer::{train_distributed, Corpus, TrainConfig};
use disco::runtime::{lit_f32, lit_i32, lit_scalar, lit_to_f32, Manifest, Runtime};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn fallback() -> AnalyticalFused {
    AnalyticalFused { launch_ms: 0.005, bw_bytes_per_ms: 4.8e8 }
}

fn chain_group(n: usize, time_ms: f64) -> FusedGroup {
    FusedGroup {
        ops: (0..n)
            .map(|i| OrigOp {
                orig_id: i,
                kind: OpKind::Mul,
                flops: 1e6,
                bytes_in: 4e5,
                bytes_out: 4e5,
                time_ms,
                duplicated: false,
            })
            .collect(),
        edges: (1..n).map(|i| (i - 1, i)).collect(),
    }
}

#[test]
fn gnn_infer_artifact_runs_and_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let pred = GnnPredictor::load(&rt, fallback()).unwrap();
    let items: Vec<(FusedGroup, f64, f64)> =
        (2..10).map(|n| (chain_group(n, 0.05), 4e5, 4e5)).collect();
    let a = pred.predict(&items).unwrap();
    let b = pred.predict(&items).unwrap();
    assert_eq!(a, b);
    assert!(a.iter().all(|&t| t > 0.0), "{a:?}");
}

#[test]
fn gnn_oversized_group_uses_fallback() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let pred = GnnPredictor::load(&rt, fallback()).unwrap();
    let big = chain_group(100, 0.05); // > MAX_NODES
    let t = pred.estimate_ms(&big, 4e5, 4e5);
    let expect = fallback().estimate_ms(&big, 4e5, 4e5);
    assert!((t - expect).abs() < 1e-12);
}

#[test]
fn gnn_training_reduces_loss_via_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    // Real pipeline: profile a graph, generate fused samples, train the
    // GNN through the exported train-step artifact.
    let g = disco::models::build(
        &disco::models::ModelSpec {
            kind: disco::models::ModelKind::Rnnlm,
            batch: 16,
            depth_scale: 0.2,
        },
        4,
    );
    let device = DeviceModel::gtx1080ti();
    let cluster = Cluster::cluster_a();
    let prof = profiler::profile(&g, &device, &cluster, 2, 11);
    let samples = profiler::generate_fused_samples(&g, &device, &prof, 192, 16, 17);
    assert!(samples.len() >= 128);

    // Hold out the tail for evaluation.
    let (train, held) = samples.split_at(samples.len() - 32);

    let rt = Runtime::new(&dir).unwrap();
    let mut trainer = GnnTrainer::new(&rt).unwrap();
    let initial_params = trainer.params.clone();
    let losses = trainer.train(train, 8).unwrap();
    let head: f64 = losses[..3].iter().sum::<f64>() / 3.0;
    let tail: f64 = losses[losses.len() - 3..].iter().sum::<f64>() / 3.0;
    assert!(
        tail < head * 0.8,
        "GNN loss did not fall: head={head:.4} tail={tail:.4}"
    );

    // Training must improve held-out log-error vs the untrained net.
    let log_err = |params: Vec<f32>| -> f64 {
        let pred = GnnPredictor::with_params(&rt, params, fallback()).unwrap();
        let items: Vec<_> =
            held.iter().map(|s| (s.group.clone(), s.bytes_in, s.bytes_out)).collect();
        let out = pred.predict(&items).unwrap();
        out.iter()
            .zip(held)
            .map(|(p, s)| (p.max(1e-5).ln() - s.label_ms.max(1e-5).ln()).abs())
            .sum::<f64>()
            / held.len() as f64
    };
    let before = log_err(initial_params);
    let after = log_err(trainer.params.clone());
    assert!(after < before * 0.8, "held-out log-err {before:.3} -> {after:.3}");
}

#[test]
fn lm_grads_and_adam_artifacts_train() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let grads_exe = rt.load("lm_grads").unwrap();
    let adam_exe = rt.load("lm_adam").unwrap();
    let lm = rt.manifest.raw.get("lm");
    let flat_len = lm.get("flat_len").as_usize().unwrap();
    let batch = lm.get("batch").as_usize().unwrap();
    let seq = lm.get("seq").as_usize().unwrap();
    let mut params = rt
        .manifest
        .load_f32(lm.get("params").as_str().unwrap())
        .unwrap();
    assert_eq!(params.len(), flat_len);

    let corpus = Corpus::synthetic(1 << 14, 3);
    let mut m = vec![0.0f32; flat_len];
    let mut v = vec![0.0f32; flat_len];
    let mut first = None;
    let mut last = 0.0;
    for step in 1..=30 {
        let tokens = corpus.batch(batch, seq, 0, 1, step);
        let out = grads_exe
            .run(&[
                lit_f32(&params, &[flat_len]).unwrap(),
                lit_i32(&tokens, &[batch, seq + 1]).unwrap(),
            ])
            .unwrap();
        let loss = lit_scalar(&out[0]).unwrap() as f64;
        let grad = lit_to_f32(&out[1]).unwrap();
        let out = adam_exe
            .run(&[
                lit_f32(&params, &[flat_len]).unwrap(),
                lit_f32(&grad, &[flat_len]).unwrap(),
                lit_f32(&m, &[flat_len]).unwrap(),
                lit_f32(&v, &[flat_len]).unwrap(),
                lit_f32(&[step as f32], &[1]).unwrap(),
            ])
            .unwrap();
        params = lit_to_f32(&out[0]).unwrap();
        m = lit_to_f32(&out[1]).unwrap();
        v = lit_to_f32(&out[2]).unwrap();
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
    }
    let first = first.unwrap();
    assert!(last < first, "loss did not fall: {first} -> {last}");
}

#[test]
fn distributed_training_replicas_stay_synchronized() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = TrainConfig { artifacts: dir, world: 2, steps: 8, eval_every: 4, seed: 5 };
    let res = train_distributed(&cfg).unwrap();
    assert_eq!(res.log.len(), 8);
    // Losses are finite and generally trending down over a short run.
    assert!(res.log.iter().all(|l| l.loss.is_finite()));
    assert!(res.log.last().unwrap().loss < res.log[0].loss * 1.05);
    // Eval happened.
    assert!(res.log.iter().any(|l| l.eval_loss.is_some()));
}
