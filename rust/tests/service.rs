//! Strategy-service integration tests (DESIGN.md §11): canonical
//! fingerprint properties, zero-simulation store hits, warm-start on
//! perturbed graphs, and the `disco serve` TCP front-end with request
//! coalescing. Everything here is deterministic per seed.

use disco::device::DeviceModel;
use disco::estimator::CostEstimator;
use disco::graph::builder::GraphBuilder;
use disco::graph::{Node, OpKind, Role, TrainingGraph};
use disco::network::Cluster;
use disco::profiler;
use disco::prop_assert;
use disco::search::SearchConfig;
use disco::service::store::frame_line;
use disco::service::{
    env_fingerprint, fsck, graph_fingerprint, plan_with_store, request, DiskFaultPlan,
    EstimatorFp, PlanSource, PlanStore, ServeOptions, Server, StoreError, WarmOptions,
};
use disco::sim::CostSource;
use disco::util::json::Json;
use disco::util::prop::{check, CaseResult, PropConfig};
use disco::util::rng::Rng;

// ---------------------------------------------------------------------------
// Shared workloads
// ---------------------------------------------------------------------------

/// Fusion-rich training workload; `extra` appends additional forward ops
/// at the end of the arena, so the common prefix keeps identical node ids
/// — a realistic "the model grew a little" perturbation.
fn workload(extra: usize) -> TrainingGraph {
    let mut b = GraphBuilder::new("svc-wl", 12);
    let x = b.constant("x", &[1 << 16]);
    let mut prev = x;
    for i in 0..5 {
        let m = b.compute(OpKind::Mul, &format!("m{i}"), &[prev], &[1 << 16], Role::Forward);
        let t = b.compute(OpKind::Tanh, &format!("t{i}"), &[m], &[1 << 16], Role::Forward);
        prev = t;
    }
    let mut grad = prev;
    for i in 0..5 {
        let gop = b.compute(OpKind::Mul, &format!("bg{i}"), &[grad], &[1 << 12], Role::Backward);
        let p = b.param(&format!("w{i}"), &[1 << 12]);
        let ar = b.allreduce(&format!("ar{i}"), gop, &[1 << 12]);
        b.optimizer_update(&format!("u{i}"), &[ar, p]);
        grad = gop;
    }
    let mut tail = prev;
    for i in 0..extra {
        tail = b.compute(OpKind::Sigmoid, &format!("x{i}"), &[tail], &[1 << 16], Role::Forward);
    }
    b.finish()
}

fn quick_cfg() -> SearchConfig {
    SearchConfig { unchanged_limit: 50, max_queue: 64, seed: 7, ..Default::default() }
}

/// Random layered DAG (mirrors tests/properties.rs) for fingerprint
/// properties.
fn random_graph(rng: &mut Rng) -> TrainingGraph {
    let layers = rng.gen_range_inclusive(2, 5);
    let width = rng.gen_range_inclusive(1, 4);
    let mut b = GraphBuilder::new("fp-prop", rng.gen_range_inclusive(2, 16));
    let mut prev: Vec<usize> = vec![b.constant("x", &[256])];
    let kinds =
        [OpKind::Mul, OpKind::Add, OpKind::Tanh, OpKind::Sigmoid, OpKind::MatMul, OpKind::Reduce];
    for l in 0..layers {
        let mut cur = Vec::new();
        for w in 0..width {
            let k = *rng.choose(&kinds).unwrap();
            let mut ins = vec![prev[rng.gen_range(prev.len())]];
            if rng.gen_bool(0.4) {
                ins.push(prev[rng.gen_range(prev.len())]); // duplicates allowed
            }
            let dims = [256usize >> rng.gen_range(3)];
            let role = if l >= layers / 2 { Role::Backward } else { Role::Forward };
            cur.push(b.compute(k, &format!("l{l}w{w}"), &ins, &dims, role));
        }
        prev = cur;
    }
    let bwd: Vec<usize> = b
        .graph()
        .live()
        .filter(|n| n.role == Role::Backward)
        .map(|n| n.id)
        .collect();
    for (i, &id) in bwd.iter().enumerate() {
        if rng.gen_bool(0.6) {
            let dims: Vec<usize> = b.graph().nodes[id].shape.dims.clone();
            let p = b.param(&format!("w{i}"), &dims);
            let ar = b.allreduce(&format!("ar{i}"), id, &dims);
            b.optimizer_update(&format!("u{i}"), &[ar, p]);
        }
    }
    b.finish()
}

/// Isomorphic copy of `g` under a random arena permutation — same graph,
/// different node ids and arena order.
fn relabel(g: &TrainingGraph, rng: &mut Rng) -> TrainingGraph {
    let n = g.nodes.len();
    let mut perm: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        let j = rng.gen_range(i + 1);
        perm.swap(i, j);
    }
    let mut nodes: Vec<Option<Node>> = vec![None; n];
    for (old, node) in g.nodes.iter().enumerate() {
        let mut m = node.clone();
        m.id = perm[old];
        m.inputs = node.inputs.iter().map(|&i| perm[i]).collect();
        m.orig_inputs = node.orig_inputs.iter().map(|&i| perm[i]).collect();
        m.ar_constituents = node.ar_constituents.iter().map(|&i| perm[i]).collect();
        if let Some(grp) = &mut m.fused {
            for o in &mut grp.ops {
                o.orig_id = perm[o.orig_id];
            }
        }
        nodes[perm[old]] = Some(m);
    }
    TrainingGraph::from_parts(
        g.name.clone(),
        nodes.into_iter().map(|n| n.unwrap()).collect(),
        g.num_workers,
    )
}

/// A cost source that fails the test if the simulator consults it — the
/// store-hit path must involve zero simulator invocations.
struct PanicCost;

impl CostSource for PanicCost {
    fn compute_time_ms(&self, node: &Node) -> f64 {
        panic!("store-hit path invoked the simulator for node {}", node.id);
    }

    fn comm_time_ms(&self, bytes: f64) -> f64 {
        panic!("store-hit path priced an AllReduce of {bytes} bytes");
    }
}

// ---------------------------------------------------------------------------
// Fingerprint properties
// ---------------------------------------------------------------------------

#[test]
fn prop_fingerprint_invariant_under_relabeling() {
    check("fp-relabel-invariant", PropConfig { cases: 64, seed: 0xF1A7 }, |rng| {
        let g = random_graph(rng);
        let h = relabel(&g, rng);
        prop_assert!(h.validate().is_ok(), "relabeled graph invalid");
        let a = graph_fingerprint(&g).unwrap();
        let b = graph_fingerprint(&h).unwrap();
        prop_assert!(a == b, "relabeling changed fingerprint: {a} vs {b}");
        CaseResult::Pass
    });
}

#[test]
fn prop_fingerprint_sensitive_to_semantic_edits() {
    check("fp-sensitive", PropConfig { cases: 64, seed: 0xF1A8 }, |rng| {
        let g = random_graph(rng);
        let base = graph_fingerprint(&g).unwrap();
        // Pick a random live compute node and perturb one feature.
        let targets: Vec<usize> = g
            .live()
            .filter(|n| n.kind.is_fusible_compute() && !n.shape.dims.is_empty())
            .map(|n| n.id)
            .collect();
        let Some(&id) = rng.choose(&targets) else {
            return CaseResult::Discard;
        };
        let mut shape = g.clone();
        shape.nodes[id].shape.dims[0] += 1;
        prop_assert!(
            graph_fingerprint(&shape).unwrap() != base,
            "shape edit on node {id} not detected"
        );
        let mut flops = g.clone();
        flops.nodes[id].flops += 1.0;
        prop_assert!(
            graph_fingerprint(&flops).unwrap() != base,
            "flops edit on node {id} not detected"
        );
        let mut kind = g.clone();
        kind.nodes[id].kind =
            if kind.nodes[id].kind == OpKind::Gelu { OpKind::Relu } else { OpKind::Gelu };
        prop_assert!(
            graph_fingerprint(&kind).unwrap() != base,
            "kind edit on node {id} not detected"
        );
        let mut workers = g.clone();
        workers.num_workers += 1;
        prop_assert!(
            graph_fingerprint(&workers).unwrap() != base,
            "worker-count edit not detected"
        );
        CaseResult::Pass
    });
}

#[test]
fn env_fingerprint_separates_cluster_estimator_and_seed() {
    let cfg = quick_cfg();
    let d = DeviceModel::gtx1080ti();
    let ana = EstimatorFp::named("analytical");
    let a = env_fingerprint(&Cluster::cluster_a(), &d, &ana, &cfg);
    assert_ne!(a, env_fingerprint(&Cluster::cluster_b(), &d, &ana, &cfg));
    assert_ne!(a, env_fingerprint(&Cluster::cluster_a(), &d, &EstimatorFp::named("oracle"), &cfg));
    assert_ne!(
        a,
        env_fingerprint(
            &Cluster::cluster_a(),
            &d,
            &ana,
            &SearchConfig { seed: 8, ..quick_cfg() }
        )
    );
    // Estimator *content* separates too: same name, different trained
    // parameters → different key (DESIGN.md §11).
    assert_ne!(
        a,
        env_fingerprint(
            &Cluster::cluster_a(),
            &d,
            &EstimatorFp::with_params("analytical", b"retrained"),
            &cfg
        )
    );
}

// ---------------------------------------------------------------------------
// Store-hit and warm-start acceptance paths
// ---------------------------------------------------------------------------

/// Acceptance: the second plan for an identical graph is served from the
/// store with ZERO simulator invocations — enforced by handing the
/// second request a cost source that panics on any query.
#[test]
fn second_plan_is_store_hit_with_zero_simulator_invocations() {
    let g = workload(0);
    let d = DeviceModel::gtx1080ti();
    let c = Cluster::cluster_a();
    let prof = profiler::profile(&g, &d, &c, 2, 5);
    let est = CostEstimator::oracle(&prof, &d);
    let cfg = quick_cfg();
    let env = env_fingerprint(&c, &d, &EstimatorFp::named("oracle"), &cfg);
    let mut store = PlanStore::in_memory(16);
    let warm = WarmOptions::default();

    let first = plan_with_store(&g, &est, &cfg, env, &mut store, &warm).unwrap();
    assert_eq!(first.source, PlanSource::Cold);
    assert!(first.best_cost_ms < first.initial_cost_ms, "search should improve");

    // Identical request, panicking cost source: any simulation panics.
    let second = plan_with_store(&g, &PanicCost, &cfg, env, &mut store, &warm).unwrap();
    assert_eq!(second.source, PlanSource::Store);
    assert_eq!(second.evals, 0);
    assert_eq!(second.best_cost_ms, first.best_cost_ms);
    assert_eq!(second.best.fingerprint(), first.best.fingerprint());
    assert!(second.best.validate().is_ok());
}

/// Acceptance: warm-starting from a cached plan of a *perturbed* graph
/// reports `steps_saved > 0`, and the warm search result is valid.
#[test]
fn warm_start_on_perturbed_graph_saves_steps() {
    let base = workload(0);
    let perturbed = workload(3);
    assert_ne!(
        graph_fingerprint(&base).unwrap(),
        graph_fingerprint(&perturbed).unwrap()
    );
    let d = DeviceModel::gtx1080ti();
    let c = Cluster::cluster_a();
    let cfg = quick_cfg();
    let env = env_fingerprint(&c, &d, &EstimatorFp::named("oracle"), &cfg);
    let mut store = PlanStore::in_memory(16);
    let warm = WarmOptions::default();

    let prof_base = profiler::profile(&base, &d, &c, 2, 5);
    let est_base = CostEstimator::oracle(&prof_base, &d);
    let first = plan_with_store(&base, &est_base, &cfg, env, &mut store, &warm).unwrap();
    assert_eq!(first.source, PlanSource::Cold);

    let prof_p = profiler::profile(&perturbed, &d, &c, 2, 5);
    let est_p = CostEstimator::oracle(&prof_p, &d);
    let out = plan_with_store(&perturbed, &est_p, &cfg, env, &mut store, &warm).unwrap();
    assert_eq!(out.source, PlanSource::Warm);
    assert!(out.warm_hits > 0);
    assert!(out.steps_saved > 0, "no cached rewrites replayed onto the perturbed graph");
    assert!(out.best.validate().is_ok());
    assert!(out.best_cost_ms <= out.initial_cost_ms);

    // Determinism: the same warm request on a fresh store with the same
    // cached plan resolves identically.
    let mut store2 = PlanStore::in_memory(16);
    let _ = plan_with_store(&base, &est_base, &cfg, env, &mut store2, &warm).unwrap();
    let out2 = plan_with_store(&perturbed, &est_p, &cfg, env, &mut store2, &warm).unwrap();
    assert_eq!(out.best_cost_ms, out2.best_cost_ms);
    assert_eq!(out.steps_saved, out2.steps_saved);
}

/// A relabeled (isomorphic) graph shares the canonical fingerprint but
/// not the arena fingerprint: it must NOT be served by blind replay; it
/// warm-starts instead. Validity is never compromised.
#[test]
fn relabeled_graph_is_not_blindly_replayed() {
    let g = workload(0);
    let mut rng = Rng::new(42);
    let relabeled = relabel(&g, &mut rng);
    assert_eq!(
        graph_fingerprint(&g).unwrap(),
        graph_fingerprint(&relabeled).unwrap()
    );
    assert_ne!(
        disco::service::arena_fingerprint(&g),
        disco::service::arena_fingerprint(&relabeled)
    );

    let d = DeviceModel::gtx1080ti();
    let c = Cluster::cluster_a();
    let cfg = quick_cfg();
    let env = env_fingerprint(&c, &d, &EstimatorFp::named("oracle"), &cfg);
    let mut store = PlanStore::in_memory(16);
    let warm = WarmOptions::default();
    let prof = profiler::profile(&g, &d, &c, 2, 5);
    let est = CostEstimator::oracle(&prof, &d);
    let _ = plan_with_store(&g, &est, &cfg, env, &mut store, &warm).unwrap();

    let prof_r = profiler::profile(&relabeled, &d, &c, 2, 5);
    let est_r = CostEstimator::oracle(&prof_r, &d);
    let out = plan_with_store(&relabeled, &est_r, &cfg, env, &mut store, &warm).unwrap();
    assert_ne!(out.source, PlanSource::Store, "must not replay onto a different arena");
    assert!(out.best.validate().is_ok());
}

/// Store hits survive a process restart (JSONL persistence).
#[test]
fn store_hit_survives_reopen() {
    let dir = std::env::temp_dir().join(format!("disco-svc-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("reopen.jsonl");
    let _ = std::fs::remove_file(&path);

    let g = workload(0);
    let d = DeviceModel::gtx1080ti();
    let c = Cluster::cluster_a();
    let prof = profiler::profile(&g, &d, &c, 2, 5);
    let est = CostEstimator::oracle(&prof, &d);
    let cfg = quick_cfg();
    let env = env_fingerprint(&c, &d, &EstimatorFp::named("oracle"), &cfg);
    let warm = WarmOptions::default();
    let first_cost = {
        let mut store = PlanStore::open(&path, 16).unwrap();
        plan_with_store(&g, &est, &cfg, env, &mut store, &warm).unwrap().best_cost_ms
    };
    let mut reopened = PlanStore::open(&path, 16).unwrap();
    let out = plan_with_store(&g, &PanicCost, &cfg, env, &mut reopened, &warm).unwrap();
    assert_eq!(out.source, PlanSource::Store);
    assert_eq!(out.best_cost_ms, first_cost);
    let _ = std::fs::remove_file(&path);
}

/// Record-format compatibility (DESIGN.md §13/§14): a bare v1 JSONL
/// line — the pre-chunking, pre-framing record format, whose mutation
/// list only carries the "ops"/"ar" tags — must load under the v3 store
/// and serve a store hit that replays UNCHUNKED with zero simulator
/// invocations. Old caches are never corrupted and never silently
/// re-searched.
#[test]
fn v1_store_lines_replay_unchunked_with_zero_sim_calls() {
    let dir = std::env::temp_dir().join(format!("disco-v1-compat-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("v1.jsonl");
    let _ = std::fs::remove_file(&path);

    let g = workload(0);
    let d = DeviceModel::gtx1080ti();
    let c = Cluster::cluster_a();
    let prof = profiler::profile(&g, &d, &c, 2, 5);
    let est = CostEstimator::oracle(&prof, &d);
    let cfg = quick_cfg(); // chunking off: the paper's fusion-only vocabulary
    let env = env_fingerprint(&c, &d, &EstimatorFp::named("oracle"), &cfg);
    let warm = WarmOptions::default();
    let first_cost = {
        let mut store = PlanStore::open(&path, 16).unwrap();
        plan_with_store(&g, &est, &cfg, env, &mut store, &warm).unwrap().best_cost_ms
    };

    // Downgrade every line to what a pre-framing v1 build wrote: strip
    // the `v3:<gen>:<len>:<crc>:` frame and set the inner version to 1.
    // With chunking off the mutation list is already v1-shaped, so the
    // rewritten file is byte-for-byte a pre-chunking store.
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(
        text.lines().all(|l| l.starts_with("v3:")),
        "expected v3-framed records on disk: {text}"
    );
    assert!(!text.contains("\"t\":\"ck\""), "fusion-only plan must carry no chunk mutations");
    let legacy: String = text
        .lines()
        .map(|l| l.splitn(5, ':').nth(4).expect("malformed v3 frame").replace("\"v\":3", "\"v\":1"))
        .map(|payload| payload + "\n")
        .collect();
    std::fs::write(&path, legacy).unwrap();

    let mut reopened = PlanStore::open(&path, 16).unwrap();
    assert_eq!(reopened.skipped, 0, "v1 lines must still parse under the v3 store");
    assert_eq!(
        reopened.recovery.legacy, reopened.recovery.total_lines,
        "bare v1 lines load as legacy verified-by-parse"
    );
    assert!(reopened.recovery.is_clean(), "an old-but-undamaged store must not be rewritten");
    let out = plan_with_store(&g, &PanicCost, &cfg, env, &mut reopened, &warm).unwrap();
    assert_eq!(out.source, PlanSource::Store);
    assert_eq!(out.evals, 0);
    assert_eq!(out.best_cost_ms, first_cost);
    assert!(!out.best.has_chunking(), "v1 record must replay unchunked");
    assert!(out.best.validate().is_ok());
    let _ = std::fs::remove_dir_all(&dir);
}

/// A plan whose mutation path includes chunk rewrites persists to JSONL
/// with the v2 "ck" tag and reloads losslessly across a reopen.
#[test]
fn chunked_plan_record_survives_reopen() {
    use disco::fusion::{FusionKind, Mutation};
    let dir = std::env::temp_dir().join(format!("disco-ck-store-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ck.jsonl");
    let _ = std::fs::remove_file(&path);

    let mut rec = shared_record("ck-key", 3.0);
    rec.muts = vec![
        Mutation::FuseOps { pred: 1, succ: 2, kind: FusionKind::NonDuplicate },
        Mutation::FuseAllReduce { a: 4, b: 5 },
        Mutation::SetChunks { ar: 7, count: 8 },
    ];
    {
        let mut store = PlanStore::open(&path, 8).unwrap();
        store.put(rec.clone()).unwrap();
    }
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.starts_with("v3:"), "record must carry the v3 durability frame: {text}");
    assert!(text.contains("\"v\":3"), "record payload must be versioned v3");
    assert!(text.contains("\"t\":\"ck\""), "chunk mutation missing from the wire: {text}");

    let reloaded = PlanStore::open(&path, 8).unwrap();
    assert_eq!(reloaded.skipped, 0);
    let got = reloaded.peek("ck-key").expect("chunked record lost across reopen");
    assert_eq!(got.muts, rec.muts, "mutation path drifted across the JSONL round-trip");
    assert_eq!(got.best_cost_ms, rec.best_cost_ms);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// TCP front-end
// ---------------------------------------------------------------------------

fn plan_request(graph: &TrainingGraph, unchanged: usize) -> Json {
    Json::obj(vec![
        ("cmd", Json::Str("plan".into())),
        ("graph", graph.to_json_value()),
        ("cluster", Json::Str("a".into())),
        ("estimator", Json::Str("oracle".into())),
        ("seed", Json::Num(7.0)),
        ("unchanged", Json::Num(unchanged as f64)),
    ])
}

fn spawn_server_with(opts: ServeOptions) -> (String, std::thread::JoinHandle<()>) {
    let server = Server::bind(&opts).unwrap();
    let addr = server.local_addr().to_string();
    let handle = std::thread::spawn(move || server.run().unwrap());
    (addr, handle)
}

fn spawn_server() -> (String, std::thread::JoinHandle<()>) {
    spawn_server_with(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        store_path: None,
        capacity: 32,
        warm: WarmOptions::default(),
        max_conns: 256,
        ..ServeOptions::default()
    })
}

#[test]
fn serve_end_to_end_second_request_is_store_hit() {
    let (addr, handle) = spawn_server();
    let g = workload(0);

    let ping = request(&addr, &Json::obj(vec![("cmd", Json::Str("ping".into()))])).unwrap();
    assert_eq!(ping.get("ok").as_bool(), Some(true));

    let first = request(&addr, &plan_request(&g, 40)).unwrap();
    assert_eq!(first.get("ok").as_bool(), Some(true), "plan failed: {first:?}");
    assert_eq!(first.get("source").as_str(), Some("cold"));
    assert!(first.get("evals").as_usize().unwrap() > 0);

    let second = request(&addr, &plan_request(&g, 40)).unwrap();
    assert_eq!(second.get("source").as_str(), Some("store"));
    assert_eq!(second.get("evals").as_usize(), Some(0));
    assert_eq!(
        second.get("best_cost_ms").as_f64(),
        first.get("best_cost_ms").as_f64()
    );
    // The returned strategy deserializes into a valid module.
    let strategy = TrainingGraph::from_json_value(second.get("strategy")).unwrap();
    assert!(strategy.validate().is_ok());

    let stats = request(&addr, &Json::obj(vec![("cmd", Json::Str("stats".into()))])).unwrap();
    assert_eq!(stats.get("searches").as_usize(), Some(1));
    assert_eq!(stats.get("store_hits").as_usize(), Some(1));
    // The `--metrics` surface (DESIGN.md §14): cold/shed/degradation
    // counters and the resolve-latency percentiles are always present.
    assert_eq!(stats.get("cold_searches").as_usize(), Some(1));
    assert_eq!(stats.get("shed_cold").as_usize(), Some(0));
    assert_eq!(stats.get("deadline_exceeded").as_usize(), Some(0));
    assert_eq!(stats.get("store_corrupt_skipped").as_usize(), Some(0));
    assert_eq!(stats.get("store_write_errors").as_usize(), Some(0));
    assert_eq!(stats.get("store_degraded").as_bool(), Some(false));
    assert!(stats.get("resolve_samples").as_usize().unwrap() >= 2, "both plans were timed");
    let p50 = stats.get("resolve_p50_ms").as_f64().unwrap();
    let p99 = stats.get("resolve_p99_ms").as_f64().unwrap();
    assert!(p50 >= 0.0 && p99 >= p50, "percentiles out of order: p50 {p50}, p99 {p99}");

    let bye = request(&addr, &Json::obj(vec![("cmd", Json::Str("shutdown".into()))])).unwrap();
    assert_eq!(bye.get("ok").as_bool(), Some(true));
    handle.join().unwrap();
}

/// Concurrent identical requests trigger exactly one search: the others
/// either coalesce onto the in-flight leader or hit the freshly stored
/// record — never a second search.
#[test]
fn serve_coalesces_concurrent_identical_requests() {
    let (addr, handle) = spawn_server();
    let g = workload(0);
    let clients = 4;
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(clients));
    let mut joins = Vec::new();
    for _ in 0..clients {
        let addr = addr.clone();
        let g = g.clone();
        let barrier = std::sync::Arc::clone(&barrier);
        joins.push(std::thread::spawn(move || {
            barrier.wait();
            request(&addr, &plan_request(&g, 60)).unwrap()
        }));
    }
    let responses: Vec<Json> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    let costs: Vec<f64> =
        responses.iter().map(|r| r.get("best_cost_ms").as_f64().unwrap()).collect();
    assert!(costs.iter().all(|&c| c == costs[0]), "divergent answers: {costs:?}");
    assert_eq!(
        responses.iter().filter(|r| r.get("source").as_str() == Some("cold")).count(),
        1,
        "exactly one client should have run the search"
    );

    let stats = request(&addr, &Json::obj(vec![("cmd", Json::Str("stats".into()))])).unwrap();
    assert_eq!(stats.get("searches").as_usize(), Some(1), "coalescing failed: {stats:?}");
    let hits = stats.get("store_hits").as_usize().unwrap();
    assert_eq!(hits, (clients - 1), "every non-leader resolves to a store hit");

    let _ = request(&addr, &Json::obj(vec![("cmd", Json::Str("shutdown".into()))])).unwrap();
    handle.join().unwrap();
}

#[test]
fn serve_rejects_malformed_requests() {
    let (addr, handle) = spawn_server();
    let bad = request(&addr, &Json::obj(vec![("cmd", Json::Str("nope".into()))])).unwrap();
    assert_eq!(bad.get("ok").as_bool(), Some(false));
    let no_graph = request(&addr, &Json::obj(vec![("cmd", Json::Str("plan".into()))])).unwrap();
    assert_eq!(no_graph.get("ok").as_bool(), Some(false));
    assert!(no_graph.get("error").as_str().unwrap().contains("graph"));
    let _ = request(&addr, &Json::obj(vec![("cmd", Json::Str("shutdown".into()))])).unwrap();
    handle.join().unwrap();
}

// ---------------------------------------------------------------------------
// Cross-process store sharing (ROADMAP gap closed by the advisory file
// lock): two PlanStores on one JSONL path, appending and compacting
// concurrently, must never lose a record.
// ---------------------------------------------------------------------------

fn shared_record(key: &str, cost: f64) -> disco::service::PlanRecord {
    disco::service::PlanRecord {
        key: key.to_string(),
        graph_fp: "g".to_string(),
        arena_fp: 0x5EED,
        model: "shared".into(),
        sketch: disco::service::GraphSketch {
            kind_counts: vec![1, 2, 3],
            live: 6,
            allreduces: 1,
            num_workers: 4,
            total_flops: 1e6,
            grad_bytes: 4096.0,
        },
        muts: vec![],
        best_cost_ms: cost,
        initial_cost_ms: cost * 2.0,
        evals: 3,
        steps: 2,
        elapsed_ms: 0.5,
    }
}

#[test]
fn store_shared_path_concurrent_appends() {
    let dir = std::env::temp_dir().join(format!("disco-shared-store-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plans.jsonl");
    let _ = std::fs::remove_file(&path);

    // Each writer repeatedly overwrites its own 5 keys, which keeps its
    // live set small while the file grows — so compaction triggers many
    // times in BOTH stores while the other is appending. Before the
    // file lock + merge-from-disk compaction, a compaction rewrote the
    // file from one store's in-memory view and silently deleted the
    // other's records.
    const WRITERS: usize = 2;
    const ROUNDS: usize = 60;
    let path2 = path.clone();
    std::thread::scope(|scope| {
        for w in 0..WRITERS {
            let p = path2.clone();
            scope.spawn(move || {
                let mut store = PlanStore::open(&p, 64).unwrap();
                for r in 0..ROUNDS {
                    let key = format!("w{w}-k{}", r % 5);
                    store.put(shared_record(&key, (r + 1) as f64)).unwrap();
                }
            });
        }
    });

    // Reload from disk: all 10 distinct keys survive, each holding the
    // LAST value its writer stored (per-key writes are single-threaded,
    // so last-write-wins is deterministic).
    let reloaded = PlanStore::open(&path, 64).unwrap();
    assert_eq!(reloaded.skipped, 0, "corrupt lines appeared under concurrency");
    for w in 0..WRITERS {
        for k in 0..5 {
            let key = format!("w{w}-k{k}");
            let rec = reloaded
                .peek(&key)
                .unwrap_or_else(|| panic!("record {key} lost by concurrent compaction"));
            // Rounds writing key k: r ≡ k (mod 5); the last is the
            // largest such r < ROUNDS.
            let last_round = (0..ROUNDS).filter(|r| r % 5 == k).max().unwrap();
            assert_eq!(rec.best_cost_ms, (last_round + 1) as f64, "{key}");
        }
    }
    // The lock file was released.
    assert!(!dir.join("plans.jsonl.lock").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn store_lock_is_stolen_from_a_dead_holder() {
    use std::io::Write as _;
    let dir = std::env::temp_dir().join(format!("disco-stale-lock-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plans.jsonl");
    let _ = std::fs::remove_file(&path);
    // Simulate a crashed holder: a lock file whose mtime is ancient.
    let lock = dir.join("plans.jsonl.lock");
    {
        let mut f = std::fs::File::create(&lock).unwrap();
        write!(f, "0").unwrap();
        f.set_modified(std::time::SystemTime::now() - std::time::Duration::from_secs(3600))
            .unwrap();
    }
    // A put must steal the stale lock instead of timing out, and must
    // release its own lock afterwards.
    let mut s = PlanStore::open(&path, 8).unwrap();
    s.put(shared_record("k", 1.0)).unwrap();
    assert!(s.peek("k").is_some());
    assert!(!lock.exists(), "lock file leaked after the put");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Store durability (DESIGN.md §14): hostile inputs, crash recovery at
// every byte offset, seeded disk-fault degradation.
// ---------------------------------------------------------------------------

/// Content spans of each line in a JSONL byte buffer: `(start, end)`
/// exclusive of the terminating newline.
fn line_spans(data: &[u8]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut start = 0usize;
    for (i, &b) in data.iter().enumerate() {
        if b == b'\n' {
            spans.push((start, i));
            start = i + 1;
        }
    }
    if start < data.len() {
        spans.push((start, data.len()));
    }
    spans
}

/// Hostile-store corpus: every damage class the recovery state machine
/// documents, in one file — a checksum failure, a length-header lie,
/// non-UTF8 bytes, a stale duplicate (higher generation EARLIER in the
/// file) and an orphan compaction snapshot. `fsck` reports it all
/// without writing; `open` recovers, serves exactly the verified
/// records and repairs the file. Zero panics anywhere.
#[test]
fn hostile_store_corpus_recovers_with_documented_outcomes() {
    let dir = std::env::temp_dir().join(format!("disco-hostile-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plans.jsonl");
    let _ = std::fs::remove_file(&path);

    let rec = |k: &str, c: f64| shared_record(k, c).to_json().to_string();
    let mut data: Vec<u8> = Vec::new();
    // 1: a valid v3 line.
    data.extend_from_slice(frame_line(1, &rec("good", 1.0)).as_bytes());
    data.push(b'\n');
    // 2: checksum failure — intact frame, one payload byte flipped.
    let mut bad_crc = frame_line(2, &rec("badcrc", 2.0)).into_bytes();
    let last = bad_crc.len() - 1;
    bad_crc[last] ^= 0x01;
    data.extend_from_slice(&bad_crc);
    data.push(b'\n');
    // 3: length-header lie (declared length ≠ payload length).
    let p = rec("badlen", 3.0);
    data.extend_from_slice(format!("v3:1:{}:{:08x}:{p}", p.len() + 7, 0).as_bytes());
    data.push(b'\n');
    // 4: non-UTF8 garbage.
    data.extend_from_slice(&[0xFF, 0xFE, 0x80, b'{', b'x', 0xC0]);
    data.push(b'\n');
    // 5+6: duplicate key, generation 5 BEFORE generation 3 — the higher
    // generation must win regardless of file order.
    data.extend_from_slice(frame_line(5, &rec("dup", 5.0)).as_bytes());
    data.push(b'\n');
    data.extend_from_slice(frame_line(3, &rec("dup", 3.0)).as_bytes());
    data.push(b'\n');
    std::fs::write(&path, &data).unwrap();
    // 7: orphan snapshot from a crash between snapshot write and rename.
    let orphan = dir.join("plans.jsonl.snap.99999");
    std::fs::write(&orphan, b"half-written snapshot").unwrap();

    // fsck without --repair: full report, zero writes.
    let report = fsck(&path, false).unwrap();
    assert_eq!(report.total_lines, 6);
    assert_eq!(report.verified, 3, "good + both dup generations verify");
    assert_eq!(report.legacy, 0);
    assert_eq!(report.corrupt, 3, "bad crc, bad length, non-UTF8");
    assert!(!report.torn_tail);
    assert_eq!(report.duplicates, 1);
    assert_eq!(report.orphan_snapshots, 1);
    assert_eq!(report.live, 2);
    assert!(!report.is_clean() && !report.repaired);
    assert_eq!(std::fs::read(&path).unwrap(), data, "fsck without --repair must not write");
    assert!(orphan.exists(), "fsck without --repair must not sweep");

    // open recovers: verified records served, higher generation wins,
    // orphan swept, file rewritten clean.
    let s = PlanStore::open(&path, 8).unwrap();
    assert_eq!(s.len(), 2);
    assert_eq!(s.peek("good"), Some(&shared_record("good", 1.0)));
    assert_eq!(s.peek("dup"), Some(&shared_record("dup", 5.0)));
    assert_eq!(s.skipped, 3);
    assert!(s.recovery.repaired);
    assert_eq!(s.recovery.orphan_snapshots, 1);
    assert!(!orphan.exists(), "open sweeps orphan snapshots");
    drop(s);
    let clean = fsck(&path, false).unwrap();
    assert!(clean.is_clean(), "repaired store must fsck clean: {clean:?}");
    assert_eq!((clean.live, clean.verified), (2, 2));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Crash-recovery property: truncate the store at EVERY byte offset
/// (a crash mid-append can stop anywhere). Reopening must recover
/// exactly the records whose full line content fits in the surviving
/// prefix — no panic, no partial record served — and the store must
/// accept new writes afterwards.
#[test]
fn crash_recovery_truncation_at_every_byte_offset() {
    let dir = std::env::temp_dir().join(format!("disco-crash-trunc-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plans.jsonl");
    let _ = std::fs::remove_file(&path);
    let keys = ["a", "b", "c"];
    {
        let mut s = PlanStore::open(&path, 8).unwrap();
        for (i, k) in keys.iter().enumerate() {
            s.put(shared_record(k, (i + 1) as f64)).unwrap();
        }
    }
    let full = std::fs::read(&path).unwrap();
    let spans = line_spans(&full);
    assert_eq!(spans.len(), keys.len());

    for cut in 0..=full.len() {
        std::fs::write(&path, &full[..cut]).unwrap();
        let s = PlanStore::open(&path, 8)
            .unwrap_or_else(|e| panic!("open failed at truncation offset {cut}: {e}"));
        // A line survives iff its full content fits in the prefix (the
        // final newline itself is optional — a complete unterminated
        // line still verifies).
        let expect = spans.iter().filter(|&&(_, end)| end <= cut).count();
        assert_eq!(s.len(), expect, "wrong survivor count at offset {cut}");
        for (i, k) in keys.iter().take(expect).enumerate() {
            assert_eq!(
                s.peek(k),
                Some(&shared_record(k, (i + 1) as f64)),
                "record {k} damaged at offset {cut}"
            );
        }
        let torn = spans.iter().any(|&(start, end)| start < cut && cut < end);
        assert_eq!(s.recovery.torn_tail, torn, "torn-tail misclassified at offset {cut}");

        // Spot-check the post-recovery write path: a put lands and the
        // store reopens clean.
        if cut % 37 == 0 {
            drop(s);
            let mut s = PlanStore::open(&path, 8).unwrap();
            s.put(shared_record("z", 99.0)).unwrap();
            drop(s);
            let r = PlanStore::open(&path, 8).unwrap();
            assert!(r.recovery.is_clean(), "post-recovery put left damage at offset {cut}");
            assert_eq!(r.len(), expect + 1);
            assert_eq!(r.peek("z"), Some(&shared_record("z", 99.0)));
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Crash-recovery property: flip one byte at EVERY offset (a garbled
/// sector). The containing line — both lines, when the flipped byte is
/// the newline joining them — must be detected and dropped; every other
/// record must survive byte-exact. The checksum makes this total: no
/// single-byte corruption can smuggle a wrong record through.
#[test]
fn crash_recovery_corruption_at_every_byte_offset() {
    let dir = std::env::temp_dir().join(format!("disco-crash-flip-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plans.jsonl");
    let _ = std::fs::remove_file(&path);
    let keys = ["a", "b", "c"];
    {
        let mut s = PlanStore::open(&path, 8).unwrap();
        for (i, k) in keys.iter().enumerate() {
            s.put(shared_record(k, (i + 1) as f64)).unwrap();
        }
    }
    let full = std::fs::read(&path).unwrap();
    let spans = line_spans(&full);

    for off in 0..full.len() {
        let mut data = full.clone();
        data[off] ^= 0x41;
        std::fs::write(&path, &data).unwrap();
        let s = PlanStore::open(&path, 8)
            .unwrap_or_else(|e| panic!("open failed with flip at offset {off}: {e}"));
        // Lines whose content contains the flip; a flipped newline
        // merges its two neighbours into one invalid line.
        let mut affected: Vec<usize> = spans
            .iter()
            .enumerate()
            .filter(|&(_, &(start, end))| off >= start && off < end)
            .map(|(i, _)| i)
            .collect();
        if affected.is_empty() {
            let i = spans.iter().position(|&(_, end)| end == off).unwrap();
            affected.push(i);
            if i + 1 < spans.len() {
                affected.push(i + 1);
            }
        }
        assert_eq!(s.len(), keys.len() - affected.len(), "survivor count at offset {off}");
        assert_eq!(
            s.recovery.corrupt + usize::from(s.recovery.torn_tail),
            1,
            "exactly one damage site at offset {off}"
        );
        for (i, k) in keys.iter().enumerate() {
            if affected.contains(&i) {
                assert!(s.peek(k).is_none(), "damaged record {k} served at offset {off}");
            } else {
                assert_eq!(
                    s.peek(k),
                    Some(&shared_record(k, (i + 1) as f64)),
                    "record {k} not byte-exact at offset {off}"
                );
            }
        }
        if off % 37 == 0 {
            drop(s);
            let mut s = PlanStore::open(&path, 8).unwrap();
            s.put(shared_record("z", 99.0)).unwrap();
            drop(s);
            let r = PlanStore::open(&path, 8).unwrap();
            assert!(r.recovery.is_clean(), "post-recovery put left damage at offset {off}");
            assert_eq!(r.peek("z"), Some(&shared_record("z", 99.0)));
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Seeded disk-fault injection (DESIGN.md §14): a torn append degrades
/// the store to memory-only for that record — the put still succeeds,
/// the record is served from memory, the damage is counted, and a
/// fault-free reopen truncates the torn bytes away.
#[test]
fn store_put_degrades_to_memory_only_on_disk_fault() {
    let dir = std::env::temp_dir().join(format!("disco-fault-put-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plans.jsonl");
    let _ = std::fs::remove_file(&path);
    // Fresh file: no open-time read, so op 1 is the first append and
    // op 2 (the second put) tears after 10 bytes.
    let plan = std::sync::Arc::new(DiskFaultPlan::parse("torn@2:10", 0xFA11).unwrap());
    let mut s = PlanStore::open_with(&path, 8, Some(plan.clone())).unwrap();
    s.put(shared_record("a", 1.0)).unwrap();
    assert!(!s.degraded);
    s.put(shared_record("b", 2.0)).unwrap();
    assert!(s.degraded, "torn append must degrade, not fail the put");
    assert_eq!(s.write_errors, 1);
    assert_eq!(s.peek("b"), Some(&shared_record("b", 2.0)), "record must stay served");
    assert_eq!(plan.ops_issued(), 2);
    drop(s);

    let r = PlanStore::open(&path, 8).unwrap();
    assert!(r.recovery.torn_tail, "the torn append is a torn tail on reopen");
    assert!(r.recovery.repaired);
    assert_eq!(r.len(), 1);
    assert!(r.peek("a").is_some() && r.peek("b").is_none());
    let _ = std::fs::remove_dir_all(&dir);
}

/// An explicit compaction whose rename step fails must surface a typed
/// [`StoreError::Io`] naming the step, leak no snapshot file, and leave
/// the original store intact.
#[test]
fn store_compact_surfaces_rename_failure_as_typed_error() {
    let dir = std::env::temp_dir().join(format!("disco-fault-compact-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plans.jsonl");
    let _ = std::fs::remove_file(&path);
    {
        let mut s = PlanStore::open(&path, 8).unwrap();
        s.put(shared_record("a", 1.0)).unwrap();
    }
    // Ops under fault: 1 = open-time read, 2 = compaction read, 3 =
    // snapshot write, 4 = the rename landing the snapshot.
    let plan = std::sync::Arc::new(DiskFaultPlan::parse("err@4", 0xFA11).unwrap());
    let mut s = PlanStore::open_with(&path, 8, Some(plan)).unwrap();
    assert!(s.recovery.is_clean());
    let err = s.compact().unwrap_err();
    match err.downcast_ref::<StoreError>() {
        Some(StoreError::Io { op, .. }) => assert_eq!(*op, "rename"),
        other => panic!("expected a typed rename StoreError, got {other:?}"),
    }
    let snap = {
        let mut os = path.as_os_str().to_os_string();
        os.push(format!(".snap.{}", std::process::id()));
        std::path::PathBuf::from(os)
    };
    assert!(!snap.exists(), "failed compaction leaked its snapshot");
    drop(s);
    let r = PlanStore::open(&path, 8).unwrap();
    assert!(r.recovery.is_clean(), "failed rename must leave the original intact");
    assert_eq!(r.peek("a"), Some(&shared_record("a", 1.0)));
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Admission control (DESIGN.md §14): cold-search cap and deadline budget.
// ---------------------------------------------------------------------------

/// `max_cold: 0` is a replay-only server: every cold request is shed
/// with a typed `retry_after` frame before any search work starts.
#[test]
fn serve_sheds_cold_searches_at_zero_cap() {
    let (addr, handle) = spawn_server_with(ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        store_path: None,
        capacity: 32,
        warm: WarmOptions::default(),
        max_conns: 256,
        cold_budget_ms: 0.0,
        max_cold: 0,
    });
    let g = workload(0);
    let resp = request(&addr, &plan_request(&g, 40)).unwrap();
    assert_eq!(resp.get("ok").as_bool(), Some(false), "got: {resp:?}");
    assert_eq!(resp.get("kind").as_str(), Some("retry_after"));
    assert!(resp.get("retry_after_ms").as_f64().unwrap() > 0.0);

    let stats = request(&addr, &Json::obj(vec![("cmd", Json::Str("stats".into()))])).unwrap();
    assert_eq!(stats.get("shed_cold").as_usize(), Some(1));
    assert_eq!(stats.get("searches").as_usize(), Some(0), "no search may have run");
    assert_eq!(stats.get("max_cold").as_usize(), Some(0));
    let _ = request(&addr, &Json::obj(vec![("cmd", Json::Str("shutdown".into()))])).unwrap();
    handle.join().unwrap();
}

/// A request whose `budget_ms` is already exhausted by the time
/// admission runs gets a typed `deadline` frame — the server never
/// starts a cold search it has no time to finish. The same request
/// without a budget is admitted (and lands under a DIFFERENT store key:
/// the budget folds into the search config's time limit, which is part
/// of the environment fingerprint).
#[test]
fn serve_enforces_request_deadline_budget() {
    let (addr, handle) = spawn_server();
    let g = workload(0);
    let mut req = plan_request(&g, 40);
    if let Json::Obj(m) = &mut req {
        m.insert("budget_ms".into(), Json::Num(1e-4));
    }
    let resp = request(&addr, &req).unwrap();
    assert_eq!(resp.get("ok").as_bool(), Some(false), "got: {resp:?}");
    assert_eq!(resp.get("kind").as_str(), Some("deadline"));
    assert_eq!(resp.get("budget_ms").as_f64(), Some(1e-4));

    let stats = request(&addr, &Json::obj(vec![("cmd", Json::Str("stats".into()))])).unwrap();
    assert_eq!(stats.get("deadline_exceeded").as_usize(), Some(1));

    let ok = request(&addr, &plan_request(&g, 40)).unwrap();
    assert_eq!(ok.get("ok").as_bool(), Some(true), "unbudgeted twin must be admitted: {ok:?}");
    assert_eq!(ok.get("source").as_str(), Some("cold"));
    let _ = request(&addr, &Json::obj(vec![("cmd", Json::Str("shutdown".into()))])).unwrap();
    handle.join().unwrap();
}
