//! Integration: the full DisCo pipeline (model → profile → search →
//! simulate) across the paper's six models, plus the baseline comparisons
//! the evaluation section is built on — at reduced scale.

use disco::baselines;
use disco::device::DeviceModel;
use disco::estimator::CostEstimator;
use disco::models::{build, ModelKind, ModelSpec};
use disco::network::Cluster;
use disco::profiler::profile;
use disco::search::{backtracking_search, SearchConfig};
use disco::sim::{fo_bound, simulate, SimOptions};

fn small(kind: ModelKind) -> ModelSpec {
    ModelSpec { kind, batch: 8, depth_scale: 0.25 }
}

#[test]
fn disco_beats_or_matches_every_baseline_on_every_model() {
    let device = DeviceModel::gtx1080ti();
    let cluster = Cluster::cluster_a();
    for kind in ModelKind::ALL {
        let g = build(&small(kind), cluster.num_devices());
        let prof = profile(&g, &device, &cluster, 2, 42);
        let est = CostEstimator::oracle(&prof, &device);
        let opts = SimOptions::default();

        let cost = |graph: &disco::graph::TrainingGraph| simulate(graph, &est, opts).makespan_ms;
        let baselines = [
            ("no_fusion", baselines::no_fusion(&g)),
            ("xla_op_fusion", baselines::xla_op_fusion(&g)),
            ("ar_fusion", baselines::ar_threshold_fusion(&g, baselines::XLA_AR_THRESHOLD)),
            ("jax_default", baselines::jax_default(&g)),
            ("ddp", baselines::pytorch_ddp(&g)),
        ];
        let best_baseline = baselines
            .iter()
            .map(|(n, bg)| (cost(bg), *n))
            .fold((f64::INFINITY, ""), |acc, x| if x.0 < acc.0 { x } else { acc });

        let cfg = SearchConfig { unchanged_limit: 120, max_queue: 64, seed: 7, ..Default::default() };
        let result = backtracking_search(&g, &est, &cfg);

        // DisCo must be at least as good as the best baseline (small slack
        // for the tiny search budget), and above the FO lower bound.
        assert!(
            result.best_cost_ms <= best_baseline.0 * 1.05,
            "{}: disco={:.3} vs best baseline {}={:.3}",
            kind.name(),
            result.best_cost_ms,
            best_baseline.1,
            best_baseline.0
        );
        // FO is a per-graph lower bound; op fusion legitimately reduces
        // total compute, so bound against the *optimized* graph.
        let fo = fo_bound(&result.best, &est);
        assert!(
            result.best_cost_ms >= fo * 0.999,
            "{}: below FO bound?! {:.3} < {:.3}",
            kind.name(),
            result.best_cost_ms,
            fo
        );
    }
}

#[test]
fn fusion_strategies_keep_semantics() {
    // Applying any baseline or the search must conserve gradient bytes
    // and represented (non-duplicated) op count.
    let device = DeviceModel::gtx1080ti();
    let cluster = Cluster::cluster_a();
    let g = build(&small(ModelKind::Transformer), 12);
    let grad_bytes = g.total_gradient_bytes();
    let repr = g.represented_ops();

    for (name, bg) in [
        ("xla", baselines::xla_op_fusion(&g)),
        ("jax_default", baselines::jax_default(&g)),
        ("ddp", baselines::pytorch_ddp(&g)),
        ("tvm", baselines::tvm_rule_fusion(&g)),
        ("ngraph", baselines::ngraph_fusion(&g)),
    ] {
        assert!(bg.validate().is_ok(), "{name}");
        assert!((bg.total_gradient_bytes() - grad_bytes).abs() < 1.0, "{name}");
        assert_eq!(bg.represented_ops(), repr, "{name}");
    }

    let prof = profile(&g, &device, &cluster, 2, 1);
    let est = CostEstimator::oracle(&prof, &device);
    let cfg = SearchConfig { unchanged_limit: 60, seed: 11, ..Default::default() };
    let r = backtracking_search(&g, &est, &cfg);
    assert!((r.best.total_gradient_bytes() - grad_bytes).abs() < 1.0);
    // Duplicate fusion may add recomputation but never loses represented ops.
    assert!(r.best.represented_ops() >= repr);
}

#[test]
fn overlap_improves_with_disco() {
    // §6.3: DisCo should raise the overlap ratio vs naive op fusion on a
    // communication-bound model.
    let device = DeviceModel::gtx1080ti();
    let cluster = Cluster::cluster_a();
    let g = build(&small(ModelKind::Vgg19), 12);
    let prof = profile(&g, &device, &cluster, 2, 13);
    let est = CostEstimator::oracle(&prof, &device);
    let opts = SimOptions::default();

    let fused = baselines::xla_op_fusion(&g);
    let r_fused = simulate(&fused, &est, opts);
    let cfg = SearchConfig { unchanged_limit: 120, seed: 5, ..Default::default() };
    let r = backtracking_search(&g, &est, &cfg);
    let r_disco = simulate(&r.best, &est, opts);
    assert!(
        r_disco.makespan_ms <= r_fused.makespan_ms,
        "disco {:.2} vs xla {:.2}",
        r_disco.makespan_ms,
        r_fused.makespan_ms
    );
}

#[test]
fn strategy_roundtrips_through_serialization() {
    // The enactment wire format must preserve the optimized module.
    let device = DeviceModel::gtx1080ti();
    let cluster = Cluster::cluster_a();
    let g = build(&small(ModelKind::ResNet50), 12);
    let prof = profile(&g, &device, &cluster, 1, 2);
    let est = CostEstimator::oracle(&prof, &device);
    let cfg = SearchConfig { unchanged_limit: 40, seed: 21, ..Default::default() };
    let r = backtracking_search(&g, &est, &cfg);
    let json = r.best.to_json();
    let back = disco::graph::TrainingGraph::from_json(&json).unwrap();
    assert_eq!(back.fingerprint(), r.best.fingerprint());
    let opts = SimOptions::default();
    assert_eq!(
        simulate(&back, &est, opts).makespan_ms,
        simulate(&r.best, &est, opts).makespan_ms
    );
}
