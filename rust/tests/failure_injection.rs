//! Failure injection: the system must reject corrupt inputs loudly rather
//! than proceed wrongly (DESIGN.md §7).

use disco::coordinator::messages::Msg;
use disco::graph::TrainingGraph;
use disco::runtime::Manifest;
use disco::util::json::Json;
use std::io::Write;
use std::net::{TcpListener, TcpStream};

#[test]
fn worker_rejects_corrupt_strategy() {
    // A leader that sends an invalid graph must get an error, not an ack.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let leader = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let hello = Msg::recv(&mut s).unwrap();
        assert!(matches!(hello, Msg::Hello { .. }));
        // Graph with a dangling input.
        Msg::Strategy {
            graph_json: r#"{"name":"bad","num_workers":2,"nodes":[
                {"id":0,"name":"x","kind":"mul","role":"fwd","inputs":[5],
                 "oinputs":[5],"shape":[4],"dtype":"f32","flops":1,"bin":1,
                 "bout":1,"deleted":false}]}"#
                .to_string(),
        }
        .send(&mut s)
        .unwrap();
        // Worker should hang up with an error, not ack.
        Msg::recv(&mut s)
    });
    let res = disco::coordinator::run_worker(
        &addr.to_string(),
        0,
        &disco::device::DeviceModel::gtx1080ti(),
        &disco::network::Cluster::cluster_a(),
    );
    assert!(res.is_err(), "worker accepted a corrupt strategy");
    let leader_saw = leader.join().unwrap();
    assert!(leader_saw.is_err(), "leader received an unexpected ack");
}

#[test]
fn oversized_frame_rejected() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let t = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        // Claim a 1 GiB frame.
        s.write_all(&(1u32 << 30).to_be_bytes()).unwrap();
        s.write_all(b"xxxx").unwrap();
    });
    let mut c = TcpStream::connect(addr).unwrap();
    assert!(Msg::recv(&mut c).is_err());
    t.join().unwrap();
}

#[test]
fn manifest_missing_and_corrupt() {
    let dir = std::env::temp_dir().join(format!("disco-missing-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    assert!(Manifest::load(&dir).is_err(), "no manifest.json");
    std::fs::write(dir.join("manifest.json"), "{broken").unwrap();
    assert!(Manifest::load(&dir).is_err(), "corrupt manifest.json");
    std::fs::write(dir.join("manifest.json"), "{}").unwrap();
    let m = Manifest::load(&dir).unwrap();
    assert!(m.artifact("nope").is_err(), "unknown artifact");
    // Truncated f32 file (length not /4).
    std::fs::write(dir.join("p.f32"), [0u8; 7]).unwrap();
    assert!(m.load_f32("p.f32").is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn graph_json_attack_surfaces() {
    // Cycles, bad enums, truncated docs — all must fail cleanly.
    for bad in [
        "",                       // empty
        "[1,2,3]",                // wrong top-level type
        r#"{"name":"x"}"#,        // missing fields
        r#"{"name":"x","num_workers":1,"nodes":[{"id":0,"name":"n","kind":"NOTAKIND","role":"fwd","inputs":[],"oinputs":[],"shape":[1],"dtype":"f32","flops":0,"bin":0,"bout":0,"deleted":false}]}"#,
    ] {
        assert!(TrainingGraph::from_json(bad).is_err(), "{bad:.40}");
    }
    // Cycle: 0 <-> 1.
    let cyc = r#"{"name":"c","num_workers":1,"nodes":[
      {"id":0,"name":"a","kind":"mul","role":"fwd","inputs":[1],"oinputs":[1],"shape":[1],"dtype":"f32","flops":0,"bin":0,"bout":0,"deleted":false},
      {"id":1,"name":"b","kind":"mul","role":"fwd","inputs":[0],"oinputs":[0],"shape":[1],"dtype":"f32","flops":0,"bin":0,"bout":0,"deleted":false}]}"#;
    assert!(TrainingGraph::from_json(cyc).is_err());
}

#[test]
fn json_parser_fuzz_never_panics() {
    // Mutate a valid document at every byte; parser must return (not panic).
    let base = r#"{"a":[1,2.5,{"b":"x"},null,true],"c":"A\n"}"#;
    let bytes = base.as_bytes();
    for i in 0..bytes.len() {
        for repl in [b'{', b'}', b'"', b'\\', b'0', b' ', 0xFFu8] {
            let mut m = bytes.to_vec();
            m[i] = repl;
            if let Ok(s) = String::from_utf8(m) {
                let _ = Json::parse(&s); // Ok or Err — both fine
            }
        }
    }
}

#[test]
fn estimator_handles_unprofiled_nodes() {
    // A graph node the profile has never seen gets the bandwidth fallback,
    // not a zero (which would corrupt the search).
    use disco::estimator::CostEstimator;
    use disco::graph::builder::GraphBuilder;
    use disco::graph::{OpKind, Role};
    use disco::sim::CostSource;

    let mut b = GraphBuilder::new("t", 2);
    let x = b.constant("x", &[1024]);
    b.compute(OpKind::Mul, "m", &[x], &[1024], Role::Forward);
    let g = b.finish();
    let prof = disco::profiler::profile(
        &g,
        &disco::device::DeviceModel::gtx1080ti(),
        &disco::network::Cluster::cluster_a(),
        1,
        1,
    );
    // New node appended after profiling.
    let mut g2 = g.clone();
    // (no builder needed; append the node manually)
    let id = g2.push(disco::graph::Node {
        id: 0,
        name: "late".into(),
        kind: OpKind::Tanh,
        role: Role::Forward,
        inputs: vec![1],
        orig_inputs: vec![1],
        shape: disco::graph::Shape::new(&[1024]),
        dtype: disco::graph::DType::F32,
        flops: 1024.0,
        bytes_in: 4096.0,
        bytes_out: 4096.0,
        fused: None,
        ar_constituents: vec![],
        deleted: false,
    });
    let est = CostEstimator::analytical(&prof, &disco::network::Cluster::cluster_a());
    assert!(est.compute_time_ms(&g2.nodes[id]) > 0.0);
}
