//! Failure injection: the system must reject corrupt inputs loudly rather
//! than proceed wrongly (DESIGN.md §7).

use disco::coordinator::messages::Msg;
use disco::graph::TrainingGraph;
use disco::runtime::Manifest;
use disco::service::server::{read_frame, write_frame};
use disco::service::{request, ServeOptions, Server};
use disco::util::json::Json;
use std::io::Write;
use std::net::{TcpListener, TcpStream};

#[test]
fn worker_rejects_corrupt_strategy() {
    // A leader that sends an invalid graph must get an error, not an ack.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let leader = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let hello = Msg::recv(&mut s).unwrap();
        assert!(matches!(hello, Msg::Hello { .. }));
        // Graph with a dangling input.
        Msg::Strategy {
            graph_json: r#"{"name":"bad","num_workers":2,"nodes":[
                {"id":0,"name":"x","kind":"mul","role":"fwd","inputs":[5],
                 "oinputs":[5],"shape":[4],"dtype":"f32","flops":1,"bin":1,
                 "bout":1,"deleted":false}]}"#
                .to_string(),
        }
        .send(&mut s)
        .unwrap();
        // Worker must announce the rejection with a typed Error frame
        // (DESIGN.md §12) — never an ack.
        Msg::recv(&mut s)
    });
    let res = disco::coordinator::run_worker(
        &addr.to_string(),
        0,
        &disco::device::DeviceModel::gtx1080ti(),
        &disco::network::Cluster::cluster_a(),
    );
    assert!(res.is_err(), "worker accepted a corrupt strategy");
    match leader.join().unwrap() {
        Ok(Msg::Error { rank, reason }) => {
            assert_eq!(rank, 0);
            assert!(reason.contains("invalid strategy"), "reason: {reason}");
        }
        other => panic!("expected a typed Error frame, got {other:?}"),
    }
}

#[test]
fn oversized_frame_rejected() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let t = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        // Claim a 1 GiB frame.
        s.write_all(&(1u32 << 30).to_be_bytes()).unwrap();
        s.write_all(b"xxxx").unwrap();
    });
    let mut c = TcpStream::connect(addr).unwrap();
    assert!(Msg::recv(&mut c).is_err());
    t.join().unwrap();
}

fn spawn_service(max_conns: usize) -> (String, std::thread::JoinHandle<()>) {
    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        store_path: None,
        max_conns,
        ..Default::default()
    };
    let server = Server::bind(&opts).unwrap();
    let addr = server.local_addr().to_string();
    let handle = std::thread::spawn(move || server.run().unwrap());
    (addr, handle)
}

fn ping(addr: &str) -> anyhow::Result<Json> {
    request(addr, &Json::obj(vec![("cmd", Json::Str("ping".into()))]))
}

/// The service front-end shares the coordinator's hardened framing: every
/// hostile input gets a typed rejection (or a silent drop for hangups),
/// and the server stays healthy afterwards.
#[test]
fn serve_survives_hostile_frames() {
    let (addr, handle) = spawn_service(256);

    // Oversized length prefix: typed rejection, no gigabyte allocation.
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(&(1u32 << 30).to_be_bytes()).unwrap();
    s.write_all(b"xxxx").unwrap();
    let reply = Json::parse(&read_frame(&mut s).unwrap()).unwrap();
    assert_eq!(reply.get("ok").as_bool(), Some(false));
    assert!(reply.get("error").as_str().unwrap().contains("exceeds"), "{reply:?}");
    drop(s);

    // Non-UTF8 body: typed rejection, then drop.
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(&2u32.to_be_bytes()).unwrap();
    s.write_all(&[0xFF, 0xFE]).unwrap();
    let reply = Json::parse(&read_frame(&mut s).unwrap()).unwrap();
    assert_eq!(reply.get("ok").as_bool(), Some(false));
    assert!(reply.get("error").as_str().unwrap().contains("UTF-8"), "{reply:?}");
    drop(s);

    // Garbage JSON in a well-formed frame: an application-level error,
    // and the connection keeps serving.
    let mut s = TcpStream::connect(&addr).unwrap();
    write_frame(&mut s, "][ not json").unwrap();
    let reply = Json::parse(&read_frame(&mut s).unwrap()).unwrap();
    assert_eq!(reply.get("ok").as_bool(), Some(false));
    assert!(reply.get("error").as_str().unwrap().contains("bad request json"), "{reply:?}");
    write_frame(&mut s, r#"{"cmd":"ping"}"#).unwrap();
    let pong = Json::parse(&read_frame(&mut s).unwrap()).unwrap();
    assert_eq!(pong.get("ok").as_bool(), Some(true));
    drop(s);

    // Mid-frame hangup: claim 100 bytes, send 10, close. The server
    // silently drops the connection — and must still be alive.
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(&100u32.to_be_bytes()).unwrap();
    s.write_all(b"0123456789").unwrap();
    drop(s);

    assert_eq!(ping(&addr).unwrap().get("ok").as_bool(), Some(true));
    let _ = request(&addr, &Json::obj(vec![("cmd", Json::Str("shutdown".into()))])).unwrap();
    handle.join().unwrap();
}

/// Beyond `max_conns` live handlers the server sheds new connections with
/// an inline `overloaded` error frame instead of spawning unboundedly —
/// and recovers as soon as the load drains.
#[test]
fn serve_sheds_load_beyond_max_conns() {
    let (addr, handle) = spawn_service(1);

    // Pin the single handler slot with an idle keep-alive connection.
    let idle = TcpStream::connect(&addr).unwrap();
    // The accept loop counts the connection before spawning its handler,
    // so shedding starts as soon as it is accepted — poll until then.
    let mut saw_shed = false;
    for _ in 0..200 {
        let r = ping(&addr).unwrap();
        if r.get("ok").as_bool() == Some(false) {
            assert!(r.get("error").as_str().unwrap().contains("overloaded"), "{r:?}");
            saw_shed = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(saw_shed, "server never shed load past max_conns=1");

    // Drain: close the idle connection, the slot frees, service resumes.
    // With max_conns=1 each request's handler may linger a beat past its
    // reply, so every follow-up retries until it lands a live slot.
    drop(idle);
    let retry_ok = |cmd: &str| -> Json {
        for _ in 0..200 {
            let r = request(&addr, &Json::obj(vec![("cmd", Json::Str(cmd.into()))])).unwrap();
            if r.get("ok").as_bool() == Some(true) {
                return r;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        panic!("server did not recover after load drained");
    };
    let stats = retry_ok("stats");
    assert!(stats.get("shed").as_usize().unwrap() >= 1, "{stats:?}");
    assert_eq!(stats.get("max_conns").as_usize(), Some(1));
    let _ = retry_ok("shutdown");
    handle.join().unwrap();
}

#[test]
fn manifest_missing_and_corrupt() {
    let dir = std::env::temp_dir().join(format!("disco-missing-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    assert!(Manifest::load(&dir).is_err(), "no manifest.json");
    std::fs::write(dir.join("manifest.json"), "{broken").unwrap();
    assert!(Manifest::load(&dir).is_err(), "corrupt manifest.json");
    std::fs::write(dir.join("manifest.json"), "{}").unwrap();
    let m = Manifest::load(&dir).unwrap();
    assert!(m.artifact("nope").is_err(), "unknown artifact");
    // Truncated f32 file (length not /4).
    std::fs::write(dir.join("p.f32"), [0u8; 7]).unwrap();
    assert!(m.load_f32("p.f32").is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn graph_json_attack_surfaces() {
    // Cycles, bad enums, truncated docs — all must fail cleanly.
    for bad in [
        "",                       // empty
        "[1,2,3]",                // wrong top-level type
        r#"{"name":"x"}"#,        // missing fields
        r#"{"name":"x","num_workers":1,"nodes":[{"id":0,"name":"n","kind":"NOTAKIND","role":"fwd","inputs":[],"oinputs":[],"shape":[1],"dtype":"f32","flops":0,"bin":0,"bout":0,"deleted":false}]}"#,
    ] {
        assert!(TrainingGraph::from_json(bad).is_err(), "{bad:.40}");
    }
    // Cycle: 0 <-> 1.
    let cyc = r#"{"name":"c","num_workers":1,"nodes":[
      {"id":0,"name":"a","kind":"mul","role":"fwd","inputs":[1],"oinputs":[1],"shape":[1],"dtype":"f32","flops":0,"bin":0,"bout":0,"deleted":false},
      {"id":1,"name":"b","kind":"mul","role":"fwd","inputs":[0],"oinputs":[0],"shape":[1],"dtype":"f32","flops":0,"bin":0,"bout":0,"deleted":false}]}"#;
    assert!(TrainingGraph::from_json(cyc).is_err());
}

#[test]
fn json_parser_fuzz_never_panics() {
    // Mutate a valid document at every byte; parser must return (not panic).
    let base = r#"{"a":[1,2.5,{"b":"x"},null,true],"c":"A\n"}"#;
    let bytes = base.as_bytes();
    for i in 0..bytes.len() {
        for repl in [b'{', b'}', b'"', b'\\', b'0', b' ', 0xFFu8] {
            let mut m = bytes.to_vec();
            m[i] = repl;
            if let Ok(s) = String::from_utf8(m) {
                let _ = Json::parse(&s); // Ok or Err — both fine
            }
        }
    }
}

#[test]
fn estimator_handles_unprofiled_nodes() {
    // A graph node the profile has never seen gets the bandwidth fallback,
    // not a zero (which would corrupt the search).
    use disco::estimator::CostEstimator;
    use disco::graph::builder::GraphBuilder;
    use disco::graph::{OpKind, Role};
    use disco::sim::CostSource;

    let mut b = GraphBuilder::new("t", 2);
    let x = b.constant("x", &[1024]);
    b.compute(OpKind::Mul, "m", &[x], &[1024], Role::Forward);
    let g = b.finish();
    let prof = disco::profiler::profile(
        &g,
        &disco::device::DeviceModel::gtx1080ti(),
        &disco::network::Cluster::cluster_a(),
        1,
        1,
    );
    // New node appended after profiling.
    let mut g2 = g.clone();
    // (no builder needed; append the node manually)
    let id = g2.push(disco::graph::Node {
        id: 0,
        name: "late".into(),
        kind: OpKind::Tanh,
        role: Role::Forward,
        inputs: vec![1],
        orig_inputs: vec![1],
        shape: disco::graph::Shape::new(&[1024]),
        dtype: disco::graph::DType::F32,
        flops: 1024.0,
        bytes_in: 4096.0,
        bytes_out: 4096.0,
        fused: None,
        ar_constituents: vec![],
        deleted: false,
    });
    let est = CostEstimator::analytical(&prof, &disco::network::Cluster::cluster_a());
    assert!(est.compute_time_ms(&g2.nodes[id]) > 0.0);
}
