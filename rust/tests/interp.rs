//! Interpreter + generated-artifact golden tests (DESIGN.md §7/§9).
//!
//! The semantics of individual HLO ops are unit-tested inside
//! `runtime::interp`; these integration tests check the *composition*:
//! the generated training artifacts compute correct losses and — the
//! strongest check we have — gradients that match finite differences
//! through the interpreter, and the GNN estimator behaves sanely next to
//! the analytical model on real model-zoo samples.

use disco::bench::gnn_pipeline::generate_samples;
use disco::bench::BenchOptions;
use disco::estimator::{AnalyticalFused, FusedOpEstimator};
use disco::graph::{FusedGroup, OpKind, OrigOp};
use disco::runtime::gnn::{encode_group, FEAT_DIM, MAX_NODES};
use disco::runtime::interp::Interp;
use disco::runtime::{corpus, gen, lit_f32, lit_i32, lit_scalar, lit_to_f32, BackendKind, Runtime};
use disco::util::rng::Rng;

fn chain_group(n: usize, time_ms: f64) -> FusedGroup {
    FusedGroup {
        ops: (0..n)
            .map(|i| OrigOp {
                orig_id: i,
                kind: OpKind::Mul,
                flops: 1e6,
                bytes_in: 4e5,
                bytes_out: 4e5,
                time_ms,
                duplicated: false,
            })
            .collect(),
        edges: (1..n).map(|i| (i - 1, i)).collect(),
    }
}

/// Encode GNN_BATCH chain groups into the (feats, adj, mask) batch.
fn gnn_batch_inputs() -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let b = gen::GNN_BATCH;
    let mut feats = vec![0.0f32; b * MAX_NODES * FEAT_DIM];
    let mut adj = vec![0.0f32; b * MAX_NODES * MAX_NODES];
    let mut mask = vec![0.0f32; b * MAX_NODES];
    let mut targets = vec![0.0f32; b];
    for slot in 0..b {
        let g = chain_group(2 + slot, 0.02 + 0.01 * slot as f64);
        let ok = encode_group(
            &g,
            4e5,
            4e5,
            &mut feats[slot * MAX_NODES * FEAT_DIM..(slot + 1) * MAX_NODES * FEAT_DIM],
            &mut adj[slot * MAX_NODES * MAX_NODES..(slot + 1) * MAX_NODES * MAX_NODES],
            &mut mask[slot * MAX_NODES..(slot + 1) * MAX_NODES],
        );
        assert!(ok);
        targets[slot] = 0.02 + 0.013 * slot as f32;
    }
    (feats, adj, mask, targets)
}

/// Run the generated gnn_train module once; returns (loss, grad) where the
/// gradient is recovered from the Adam state: with m=0 in, m' = 0.1·g.
fn gnn_train_step(interp: &Interp, params: &[f32]) -> (f64, Vec<f32>) {
    let n = params.len();
    let b = gen::GNN_BATCH;
    let (feats, adj, mask, targets) = gnn_batch_inputs();
    let zeros = vec![0.0f32; n];
    let out = interp
        .run(&[
            lit_f32(params, &[n]).unwrap(),
            lit_f32(&zeros, &[n]).unwrap(),
            lit_f32(&zeros, &[n]).unwrap(),
            lit_f32(&[1.0], &[1]).unwrap(),
            lit_f32(&feats, &[b, MAX_NODES, FEAT_DIM]).unwrap(),
            lit_f32(&adj, &[b, MAX_NODES, MAX_NODES]).unwrap(),
            lit_f32(&mask, &[b, MAX_NODES]).unwrap(),
            lit_f32(&targets, &[b]).unwrap(),
        ])
        .unwrap();
    let loss = lit_scalar(&out[0]).unwrap() as f64;
    let m2 = lit_to_f32(&out[2]).unwrap();
    let grad: Vec<f32> = m2.iter().map(|&m| m * 10.0).collect();
    (loss, grad)
}

#[test]
fn gnn_train_gradients_match_finite_differences() {
    let interp = Interp::from_text(&gen::gnn_train_hlo()).unwrap();
    let params = gen::gnn_init_params();
    let (loss0, grad) = gnn_train_step(&interp, &params);
    assert!(loss0.is_finite() && loss0 > 0.0, "loss0={loss0}");

    // One probe index inside every parameter block of the flat layout.
    let (f, h, m) = (FEAT_DIM, 16usize, 16usize);
    let w_in = f * h;
    let probes = [
        0,                          // W_in
        w_in + 3,                   // b_in
        w_in + h + 7,               // W1
        w_in + h + h * h + 1,       // b1
        w_in + h + h * h + h + 5,   // Wm1
        gen::gnn_flat_len() - m - 2, // bm1 (just before Wm2 block)
        gen::gnn_flat_len() - 2,    // Wm2 last element
        gen::gnn_flat_len() - 1,    // bm2
    ];
    let eps = 1e-2f32;
    for &i in &probes {
        let mut up = params.clone();
        up[i] += eps;
        let (lu, _) = gnn_train_step(&interp, &up);
        let mut dn = params.clone();
        dn[i] -= eps;
        let (ld, _) = gnn_train_step(&interp, &dn);
        let fd = (lu - ld) / (2.0 * eps as f64);
        let g = grad[i] as f64;
        let tol = 0.05 * g.abs().max(1.0);
        assert!(
            (fd - g).abs() < tol,
            "param {i}: finite-diff {fd:.5} vs analytic {g:.5}"
        );
    }
}

#[test]
fn gnn_infer_matches_train_side_forward() {
    // exp(yv) from the infer module must be consistent with the loss the
    // train module reports: loss = mean((ln pred − ln target)²).
    let infer = Interp::from_text(&gen::gnn_infer_hlo()).unwrap();
    let train = Interp::from_text(&gen::gnn_train_hlo()).unwrap();
    let params = gen::gnn_init_params();
    let n = params.len();
    let b = gen::GNN_BATCH;
    let (feats, adj, mask, targets) = gnn_batch_inputs();
    let out = infer
        .run(&[
            lit_f32(&params, &[n]).unwrap(),
            lit_f32(&feats, &[b, MAX_NODES, FEAT_DIM]).unwrap(),
            lit_f32(&adj, &[b, MAX_NODES, MAX_NODES]).unwrap(),
            lit_f32(&mask, &[b, MAX_NODES]).unwrap(),
        ])
        .unwrap();
    let preds = lit_to_f32(&out[0]).unwrap();
    assert_eq!(preds.len(), b);
    assert!(preds.iter().all(|p| p.is_finite() && *p > 0.0), "{preds:?}");
    let expected_loss = preds
        .iter()
        .zip(&targets)
        .map(|(&p, &t)| {
            let d = (p as f64).ln() - (t as f64).max(1e-5).ln();
            d * d
        })
        .sum::<f64>()
        / b as f64;
    let (loss, _) = gnn_train_step(&train, &params);
    assert!(
        (loss - expected_loss).abs() < 1e-3 * expected_loss.max(1.0),
        "train loss {loss} vs recomputed {expected_loss}"
    );
}

#[test]
fn lm_loss_at_zero_params_is_uniform_entropy() {
    let interp = Interp::from_text(&gen::lm_eval_hlo()).unwrap();
    let l = gen::lm_flat_len();
    let (b, s, v) = (gen::LM_BATCH, gen::LM_SEQ, gen::LM_VOCAB);
    let tokens: Vec<i32> = (0..b * (s + 1)).map(|i| (i * 7 % 96) as i32 + 32).collect();
    let out = interp
        .run(&[
            lit_f32(&vec![0.0; l], &[l]).unwrap(),
            disco::runtime::lit_i32(&tokens, &[b, s + 1]).unwrap(),
        ])
        .unwrap();
    let loss = lit_scalar(&out[0]).unwrap() as f64;
    let uniform = (v as f64).ln();
    assert!(
        (loss - uniform).abs() < 1e-3,
        "uniform-logit loss {loss} vs ln({v}) = {uniform}"
    );
}

#[test]
fn lm_adam_moves_params_against_gradient() {
    let interp = Interp::from_text(&gen::lm_adam_hlo()).unwrap();
    let l = gen::lm_flat_len();
    let p = vec![0.5f32; l];
    let mut g = vec![0.0f32; l];
    g[0] = 1.0; // positive gradient → param must decrease
    g[1] = -1.0; // negative gradient → param must increase
    let zeros = vec![0.0f32; l];
    let out = interp
        .run(&[
            lit_f32(&p, &[l]).unwrap(),
            lit_f32(&g, &[l]).unwrap(),
            lit_f32(&zeros, &[l]).unwrap(),
            lit_f32(&zeros, &[l]).unwrap(),
            lit_f32(&[1.0], &[1]).unwrap(),
        ])
        .unwrap();
    let p2 = lit_to_f32(&out[0]).unwrap();
    assert!(p2[0] < 0.5, "p2[0]={}", p2[0]);
    assert!(p2[1] > 0.5, "p2[1]={}", p2[1]);
    // Zero gradient → parameter untouched (Adam has no weight decay).
    assert!((p2[2] - 0.5).abs() < 1e-7, "p2[2]={}", p2[2]);
    // Bias-corrected first step ≈ lr · sign(g).
    let lr = gen::LM_LR as f32;
    assert!((0.5 - p2[0] - lr).abs() < lr * 0.05, "step={}", 0.5 - p2[0]);
}

// ---------------------------------------------------------------------------
// Golden conformance corpus (DESIGN.md §9): every .hlo file under
// tests/hlo_corpus/ executes and its `// expect:` directives must hold.
// ---------------------------------------------------------------------------

fn corpus_files() -> Vec<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/hlo_corpus");
    let mut files: Vec<_> = std::fs::read_dir(&dir)
        .expect("tests/hlo_corpus exists")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|e| e == "hlo"))
        .collect();
    files.sort();
    files
}

#[test]
fn conformance_corpus() {
    let files = corpus_files();
    assert!(files.len() >= 25, "conformance corpus has only {} cases", files.len());
    let mut failures = Vec::new();
    for f in &files {
        let name = f.file_name().unwrap().to_string_lossy().into_owned();
        // A case without expectations verifies nothing — reject it so a
        // forgotten `// expect:` line can't silently pass.
        let text = std::fs::read_to_string(f).unwrap();
        match corpus::parse_case(&name, &text) {
            Ok(case) if case.expects.is_empty() => {
                failures.push(format!("{name}: no expect directives"));
                continue;
            }
            Err(e) => {
                failures.push(format!("{name}: {e:#}"));
                continue;
            }
            Ok(_) => {}
        }
        if let Err(e) = corpus::run_file(f) {
            failures.push(format!("{name}: {e:#}"));
        }
    }
    assert!(
        failures.is_empty(),
        "{} of {} corpus case(s) failed:\n{}",
        failures.len(),
        files.len(),
        failures.join("\n")
    );
}

#[test]
fn corpus_covers_every_new_op_family() {
    // The corpus is the proof the op set is sufficient — make sure no
    // family can be silently dropped from it.
    let all: String = corpus_files()
        .iter()
        .map(|f| std::fs::read_to_string(f).unwrap())
        .collect::<Vec<_>>()
        .join("\n");
    for needle in [
        " gather(", " scatter(", " dynamic-slice(", " dynamic-update-slice(", " while(",
        " conditional(", " call(", " pad(", " reverse(", " clamp(", "f16[", "bf16[",
        "pred[", "s32[",
    ] {
        assert!(all.contains(needle), "corpus lost coverage of '{needle}'");
    }
}

// ---------------------------------------------------------------------------
// Mixed-precision training-step artifact (gather + while + scatter + f16):
// finite differences validate the hand-derived backward end-to-end,
// including through the while-loop call-frame path.
// ---------------------------------------------------------------------------

fn embed_tokens_targets() -> (Vec<i32>, Vec<f32>) {
    // Row 2 is referenced three times (scatter-add accumulation), rows
    // 3/4/6 … never (their gradient must be exactly zero).
    (vec![1, 2, 1, 5, 0, 2, 7, 2], vec![0.5, -0.3])
}

/// One embed_grads step: returns (loss, grad).
fn embed_step(interp: &Interp, params: &[f32]) -> (f64, Vec<f32>) {
    let (b, s) = (gen::EMBED_BATCH, gen::EMBED_SEQ);
    let (tokens, targets) = embed_tokens_targets();
    let out = interp
        .run(&[
            lit_f32(params, &[params.len()]).unwrap(),
            lit_i32(&tokens, &[b, s]).unwrap(),
            lit_f32(&targets, &[b]).unwrap(),
        ])
        .unwrap();
    (lit_scalar(&out[0]).unwrap() as f64, lit_to_f32(&out[1]).unwrap())
}

fn embed_params(seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..gen::embed_flat_len()).map(|_| (rng.gen_normal() * 0.2) as f32).collect()
}

#[test]
fn embed_grads_match_finite_differences_through_gather_scatter_f16() {
    let interp = Interp::from_text(&gen::embed_grads_hlo()).unwrap();
    let params = embed_params(0xE4B);
    let (loss0, grad) = embed_step(&interp, &params);
    assert!(loss0.is_finite() && loss0 > 0.0, "loss0={loss0}");
    let d = gen::EMBED_DIM;
    // Probe rows hit once (1, 5), three times (2), and never (3).
    let probes = [d + 0, d + 3, 2 * d + 1, 2 * d + 7, 5 * d + 2, 3 * d + 4];
    let eps = 2e-2f32;
    for &i in &probes {
        let mut up = params.clone();
        up[i] += eps;
        let (lu, _) = embed_step(&interp, &up);
        let mut dn = params.clone();
        dn[i] -= eps;
        let (ld, _) = embed_step(&interp, &dn);
        let fd = (lu - ld) / (2.0 * eps as f64);
        let g = grad[i] as f64;
        // Tolerance absorbs the f16 cast-pair quantization (quantum
        // ≈ 2.4e-4 against a 4e-2 probe span).
        let tol = 0.05 * g.abs().max(0.2);
        assert!((fd - g).abs() < tol, "param {i}: finite-diff {fd:.5} vs analytic {g:.5}");
    }
    // Never-referenced rows have exactly zero gradient.
    for j in 0..d {
        assert_eq!(grad[3 * d + j], 0.0, "untouched row leaked gradient at col {j}");
    }
}

#[test]
fn while_loop_gradient_matches_finite_differences() {
    // Dedicated guard on the call-frame path: the loss flows through a
    // real `while` (sequence pooling), so any drift in carried-tuple
    // evaluation shows up as a gradient mismatch here.
    let interp = Interp::from_text(&gen::embed_grads_hlo()).unwrap();
    let params = embed_params(0x3117);
    let (_, grad) = embed_step(&interp, &params);
    let d = gen::EMBED_DIM;
    let eps = 2e-2f32;
    for &i in &[0, 2 * d + 3, 7 * d + 5] {
        let mut up = params.clone();
        up[i] += eps;
        let mut dn = params.clone();
        dn[i] -= eps;
        let fd = (embed_step(&interp, &up).0 - embed_step(&interp, &dn).0) / (2.0 * eps as f64);
        let g = grad[i] as f64;
        assert!(
            (fd - g).abs() < 0.05 * g.abs().max(0.2),
            "param {i}: finite-diff {fd:.5} vs analytic {g:.5}"
        );
    }
}

#[test]
fn probe_ops_artifact_hits_every_remaining_family() {
    let interp = Interp::from_text(&gen::probe_ops_hlo()).unwrap();
    let v = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[4]).unwrap();
    let sel = lit_i32(&[1], &[]).unwrap();
    let out = interp.run(&[v, sel]).unwrap();
    // pad 1_2_1 over [1,2,3,4] with value 0.
    assert_eq!(
        lit_to_f32(&out[0]).unwrap(),
        vec![0.0, 1.0, 0.0, 2.0, 0.0, 3.0, 0.0, 4.0, 0.0, 0.0]
    );
    // conditional true branch negates.
    assert_eq!(lit_to_f32(&out[1]).unwrap(), vec![-1.0, -2.0, -3.0, -4.0]);
    // dynamic-update-slice writes [1,2] into reverse(v) at offset 2.
    assert_eq!(lit_to_f32(&out[2]).unwrap(), vec![4.0, 3.0, 1.0, 2.0]);
    // bf16 round-trip is exact on small integers.
    assert_eq!(lit_to_f32(&out[3]).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    // false branch halves instead.
    let v = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[4]).unwrap();
    let sel = lit_i32(&[0], &[]).unwrap();
    let out = interp.run(&[v, sel]).unwrap();
    assert_eq!(lit_to_f32(&out[1]).unwrap(), vec![2.0, 1.5, 1.0, 0.5]);
}

#[test]
fn gnn_and_analytical_predictions_are_finite_and_sane_on_zoo() {
    // Parity satellite: on real model-zoo fused-op samples, the (untrained)
    // GNN estimator and the analytical model must both produce finite,
    // positive, same-ballpark predictions, and the GNN's batch path must
    // agree with its scalar path.
    let opts = BenchOptions::default();
    let samples = generate_samples(&opts, 8, 12, 0x51EE);
    assert!(samples.len() >= 24);
    let dir = std::env::temp_dir().join(format!("disco-parity-{}", std::process::id()));
    let rt = Runtime::with_backend(&dir, BackendKind::Interp).unwrap();
    let fallback = AnalyticalFused { launch_ms: 0.005, bw_bytes_per_ms: 4.8e8 };
    let pred = disco::runtime::gnn::GnnPredictor::load(&rt, fallback).unwrap();

    let items: Vec<(FusedGroup, f64, f64)> = samples
        .iter()
        .take(40)
        .map(|s| (s.group.clone(), s.bytes_in, s.bytes_out))
        .collect();
    let gnn = pred.predict(&items).unwrap();
    let ana = AnalyticalFused { launch_ms: 0.005, bw_bytes_per_ms: 4.8e8 };
    for ((group, bi, bo), &g) in items.iter().zip(&gnn) {
        let a = ana.estimate_ms(group, *bi, *bo);
        assert!(g.is_finite() && g > 0.0, "gnn pred {g}");
        assert!(a.is_finite() && a > 0.0, "analytical pred {a}");
        // Untrained net vs white-box heuristic: same universe, not equal.
        assert!((g / a).ln().abs() < 20.0, "gnn {g} vs analytical {a}");
    }
    // Scalar path consistency (same artifact, same encoding).
    let (g0, bi0, bo0) = &items[0];
    let single = pred.estimate_ms(g0, *bi0, *bo0);
    assert!((single - gnn[0]).abs() < 1e-9, "batch {} vs scalar {single}", gnn[0]);
    std::fs::remove_dir_all(&dir).ok();
}
