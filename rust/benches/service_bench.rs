//! Strategy-service microbenches: canonical-fingerprint throughput, plan
//! store put/get, and the request-level payoff — cold search vs store
//! hit vs warm-started search on the acceptance workload. These are the
//! engineering numbers behind DESIGN.md §11's amortization claim: a
//! store hit replaces an entire profile + search with one mutation
//! replay.

use disco::device::DeviceModel;
use disco::estimator::CostEstimator;
use disco::models::{build, ModelSpec};
use disco::network::Cluster;
use disco::profiler::profile;
use disco::search::{backtracking_search, backtracking_search_seeded, SearchConfig};
use disco::service::{graph_fingerprint, GraphSketch, PlanRecord, PlanStore};
use disco::util::timer::black_box;
use std::time::Instant;

fn main() {
    let cluster = Cluster::cluster_a();
    let device = DeviceModel::gtx1080ti();
    let g = build(&ModelSpec::transformer_base(), cluster.num_devices());

    // Canonical fingerprint throughput (two FNV lanes over the arena).
    let iters = 200;
    let start = Instant::now();
    for _ in 0..iters {
        black_box(graph_fingerprint(&g).unwrap());
    }
    let per = start.elapsed().as_secs_f64() / iters as f64;
    println!(
        "service/fingerprint    {:>5} live nodes   {:>8.1} us/fp   ({:.0} fps/s)",
        g.live_count(),
        per * 1e6,
        1.0 / per
    );

    // Store put/get on an in-memory index (the disk append is one
    // JSONL line; load cost is measured by reopening in tests).
    let sketch = GraphSketch::of(&g);
    let mut store = PlanStore::in_memory(4096);
    let n = 2000usize;
    let start = Instant::now();
    for i in 0..n {
        let rec = PlanRecord {
            key: format!("{i:032x}"),
            graph_fp: format!("{:032x}", i / 4),
            arena_fp: i as u64,
            model: "bench".into(),
            sketch: sketch.clone(),
            muts: Vec::new(),
            best_cost_ms: i as f64,
            initial_cost_ms: 2.0 * i as f64,
            evals: 1,
            steps: 1,
            elapsed_ms: 0.0,
        };
        store.put(rec).unwrap();
    }
    let put_s = start.elapsed().as_secs_f64();
    let start = Instant::now();
    for i in 0..n {
        black_box(store.get(&format!("{i:032x}")));
    }
    let get_s = start.elapsed().as_secs_f64();
    println!(
        "service/store          {n} records   put {:>7.1} us/op   get {:>7.1} us/op",
        put_s / n as f64 * 1e6,
        get_s / n as f64 * 1e6
    );

    // Request-level: cold search vs warm-started search vs replay-only
    // (what a store hit costs the server).
    let prof = profile(&g, &device, &cluster, 2, 1);
    let est = CostEstimator::oracle(&prof, &device);
    let cfg = SearchConfig { unchanged_limit: 150, seed: 3, track_best_path: true, ..Default::default() };
    let start = Instant::now();
    let cold = backtracking_search(&g, &est, &cfg);
    let cold_s = start.elapsed().as_secs_f64();
    let seeds = vec![cold.best_path.clone()];
    let start = Instant::now();
    let warm = backtracking_search_seeded(&g, &est, &cfg, &seeds);
    let warm_s = start.elapsed().as_secs_f64();
    let start = Instant::now();
    let mut replayed = g.clone();
    for m in &cold.best_path {
        m.replay(&mut replayed).unwrap();
    }
    let hit_s = start.elapsed().as_secs_f64();
    black_box(replayed);
    println!(
        "service/plan           cold {:>7.2}s ({} evals)   warm {:>7.2}s (saved {} steps)   hit {:>9.2} ms",
        cold_s,
        cold.evals,
        warm_s,
        warm.steps_saved,
        hit_s * 1e3
    );
    println!(
        "service/plan           warm best {:.3} ms <= cold best {:.3} ms   hit speedup over cold: {:.0}x",
        warm.best_cost_ms,
        cold.best_cost_ms,
        cold_s / hit_s.max(1e-9)
    );
}
