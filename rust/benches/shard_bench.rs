//! Gradient-sharding A/B (`cargo bench --bench shard_bench`): on two
//! comm-heavy model-zoo entries, compare the best plan found by the
//! paper's fusion-only vocabulary (DDP semantics: whole-tensor
//! AllReduces) against a joint fusion+sharding search warm-started from
//! the DDP winner (so the sharded arm is a guaranteed-no-worse
//! refinement, and any gap is what ZeRO/FSDP-style
//! reduce-scatter/all-gather scheduling bought — sharded optimizer
//! compute plus the all-gather hidden behind the next forward pass).
//! Upserts the `shard_bench` line of `BENCH_search.json` at the repo
//! root, leaving other arms' lines intact.

use disco::bench::{write_shard_bench_record, BenchOptions, Scale};

fn main() {
    let opts = BenchOptions { scale: Scale::Full, ..Default::default() };
    match write_shard_bench_record(&opts) {
        Ok((record, path)) => {
            println!(
                "shard_bench: seed {} unchanged_limit {}",
                record.seed, record.unchanged_limit
            );
            for m in &record.models {
                println!(
                    "  {:<18} {:>2}w  initial {:>8.3} ms  DDP {:>8.3} ms  \
                     +sharding {:>8.3} ms  ({:.3}x, {} sharded ARs, {} evals)",
                    m.model,
                    m.workers,
                    m.initial_ms,
                    m.ddp_ms,
                    m.sharded_ms,
                    m.speedup(),
                    m.sharded_ars,
                    m.sharded_evals
                );
            }
            println!("wrote shard_bench record to {}", path.display());
        }
        Err(e) => eprintln!("failed to write shard_bench record: {e}"),
    }
}
