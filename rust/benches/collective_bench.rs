//! Collective substrate: in-process ring AllReduce throughput across
//! message sizes and world sizes (the enactment path's real collective).

use disco::collective::run_workers;
use disco::util::timer::fmt_ns;
use std::time::Instant;

fn main() {
    for world in [2usize, 4, 8] {
        for log2 in [10usize, 14, 18, 22] {
            let elems = 1usize << log2;
            let iters = if log2 >= 18 { 20 } else { 200 };
            let t = Instant::now();
            run_workers(world, move |peer| {
                let mut data = vec![peer.rank as f32; elems];
                for _ in 0..iters {
                    peer.allreduce_sum(&mut data);
                }
            });
            let per = t.elapsed().as_nanos() as f64 / iters as f64;
            let bytes = elems * 4;
            let gbps = bytes as f64 / (per / 1e9) / 1e9;
            println!(
                "allreduce world={world} size={:>8}B: {:>12}/op  {:>6.2} GB/s algbw",
                bytes,
                fmt_ns(per),
                gbps
            );
        }
    }
}
