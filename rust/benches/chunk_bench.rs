//! Chunked-collectives A/B (`cargo bench --bench chunk_bench`): on two
//! comm-heavy model-zoo entries, compare the best plan found by the
//! paper's fusion-only vocabulary against a joint fusion+chunking search
//! warm-started from the fusion-only winner (so the chunked arm is a
//! guaranteed-no-worse refinement, and any gap is overlap the chunk
//! vocabulary bought). Upserts the `chunk_bench` line of
//! `BENCH_search.json` at the repo root, leaving other arms' lines
//! intact.

use disco::bench::{write_chunk_bench_record, BenchOptions, Scale};

fn main() {
    let opts = BenchOptions { scale: Scale::Full, ..Default::default() };
    match write_chunk_bench_record(&opts) {
        Ok((record, path)) => {
            println!(
                "chunk_bench: seed {} unchanged_limit {} max_chunks {}",
                record.seed, record.unchanged_limit, record.max_chunks
            );
            for m in &record.models {
                println!(
                    "  {:<18} {:>2}w  initial {:>8.3} ms  fusion-only {:>8.3} ms  \
                     +chunking {:>8.3} ms  ({:.3}x, {} chunked ARs, {} evals)",
                    m.model,
                    m.workers,
                    m.initial_ms,
                    m.unchunked_ms,
                    m.chunked_ms,
                    m.speedup(),
                    m.chunked_ars,
                    m.chunked_evals
                );
            }
            println!("wrote chunk_bench record to {}", path.display());
        }
        Err(e) => eprintln!("failed to write chunk_bench record: {e}"),
    }
}
