//! L3 hot path: cost-model simulation throughput (`Cost(H)` is called
//! thousands of times per search — DESIGN.md §8 target ≥ 10k simulated
//! ops/ms), measured both the pre-refactor way (fresh scratch allocations
//! + adjacency per call) and with the reused [`SimWorkspace`] + cached
//! CSR adjacency the search actually uses.

use disco::device::DeviceModel;
use disco::estimator::CostEstimator;
use disco::models::{build, ModelKind, ModelSpec};
use disco::network::Cluster;
use disco::profiler::profile;
use disco::sim::hifi::{execute_real, HifiOptions};
use disco::sim::{simulate, simulate_in, NoRecord, SimOptions, SimWorkspace};
use disco::util::timer::{bench_quick, black_box};

fn main() {
    let cluster = Cluster::cluster_a();
    let device = DeviceModel::gtx1080ti();

    for (name, spec) in [
        ("rnnlm-fast", ModelSpec { kind: ModelKind::Rnnlm, batch: 16, depth_scale: 0.25 }),
        ("transformer-full", ModelSpec::transformer_base()),
        ("bert-full", ModelSpec::bert_base()),
    ] {
        let mut g = build(&spec, cluster.num_devices());
        let prof = profile(&g, &device, &cluster, 2, 1);
        let est = CostEstimator::oracle(&prof, &device);
        let ops = g.live_count();

        // Before: fresh workspace per call, adjacency rebuilt per call
        // (the pre-refactor per-eval allocation profile).
        let fresh = bench_quick(&format!("simulate/fresh-alloc/{name} ({ops} ops)"), || {
            g.invalidate_adjacency();
            black_box(simulate(&g, &est, SimOptions::default()));
        });

        // After: reused workspace + cached CSR (the search hot path).
        let mut ws = SimWorkspace::new();
        let reused = bench_quick(&format!("simulate/reused-ws/{name} ({ops} ops)"), || {
            black_box(simulate_in(&g, &est, SimOptions::default(), &mut NoRecord, &mut ws));
        });

        let ops_per_ms = ops as f64 / (reused.mean_ns / 1e6);
        println!(
            "  -> {ops_per_ms:.0} simulated ops/ms reused ({:.2}x vs fresh-alloc)",
            fresh.mean_ns / reused.mean_ns
        );
    }

    // Hi-fi execution (Table 2's "real run") — noisy, multi-iteration.
    let g = build(&ModelSpec { kind: ModelKind::Rnnlm, batch: 16, depth_scale: 0.25 }, 12);
    bench_quick("hifi_execute/rnnlm-fast x5 iters", || {
        black_box(execute_real(&g, &device, &cluster, &HifiOptions::default()));
    });
}
