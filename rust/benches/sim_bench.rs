//! L3 hot path: cost-model simulation throughput (`Cost(H)` is called
//! thousands of times per search — DESIGN.md §8 target ≥ 10k simulated
//! ops/ms), measured both the pre-refactor way (fresh scratch allocations
//! + adjacency per call) and with the reused [`SimWorkspace`] + cached
//! CSR adjacency the search actually uses.

use disco::device::DeviceModel;
use disco::estimator::CostEstimator;
use disco::fusion::{fuse_ops_explain, op_fusion_candidates, FusionKind};
use disco::models::{build, ModelKind, ModelSpec};
use disco::network::Cluster;
use disco::profiler::profile;
use disco::sim::hifi::{execute_real, HifiOptions};
use disco::sim::{
    simulate, simulate_ckpt_in, simulate_delta, simulate_in, simulate_table_in, CheckpointLog,
    CostTable, NoRecord, SimOptions, SimWorkspace,
};
use disco::util::timer::{bench_quick, black_box};

fn main() {
    let cluster = Cluster::cluster_a();
    let device = DeviceModel::gtx1080ti();

    for (name, spec) in [
        ("rnnlm-fast", ModelSpec { kind: ModelKind::Rnnlm, batch: 16, depth_scale: 0.25 }),
        ("transformer-full", ModelSpec::transformer_base()),
        ("bert-full", ModelSpec::bert_base()),
    ] {
        let mut g = build(&spec, cluster.num_devices());
        let prof = profile(&g, &device, &cluster, 2, 1);
        let est = CostEstimator::oracle(&prof, &device);
        let ops = g.live_count();

        // Before: fresh workspace per call, adjacency rebuilt per call
        // (the pre-refactor per-eval allocation profile).
        let fresh = bench_quick(&format!("simulate/fresh-alloc/{name} ({ops} ops)"), || {
            g.invalidate_adjacency();
            black_box(simulate(&g, &est, SimOptions::default()));
        });

        // After: reused workspace + cached CSR (the PR-1 hot path).
        let mut ws = SimWorkspace::new();
        let reused = bench_quick(&format!("simulate/reused-ws/{name} ({ops} ops)"), || {
            black_box(simulate_in(&g, &est, SimOptions::default(), &mut NoRecord, &mut ws));
        });

        // Cost-table event loop: per-node costs resolved once per call
        // into flat arrays, zero dyn calls / locks per scheduled event
        // (build included in the measurement — the search rebuilds the
        // table per candidate).
        let mut table = CostTable::new();
        let tabled = bench_quick(&format!("simulate/cost-table/{name} ({ops} ops)"), || {
            table.build_in(&g, &est);
            black_box(simulate_table_in(&g, &table, SimOptions::default(), &mut NoRecord, &mut ws));
        });

        // Delta replay: parent simulated once with checkpoints (outside
        // the timed loop, as in the search where ≤3 children share it),
        // then each iteration replays one late-mutation child's suffix.
        let parent = g.clone();
        let mut parent_table = CostTable::new();
        parent_table.build_in(&parent, &est);
        let mut log = CheckpointLog::new();
        let _ = simulate_ckpt_in(
            &parent,
            &parent_table,
            SimOptions::default(),
            &mut NoRecord,
            &mut ws,
            &mut log,
            0,
        );
        let mut child = parent.clone();
        let (p, s) = *op_fusion_candidates(&parent).last().expect("no fusion candidates");
        let fx = fuse_ops_explain(&mut child, p, s, FusionKind::NonDuplicate)
            .or_else(|_| fuse_ops_explain(&mut child, p, s, FusionKind::Duplicate))
            .expect("fusion failed");
        let mut frontier = vec![p, s];
        fx.extend_frontier(&child, &mut frontier);
        let mut child_table = CostTable::new();
        child_table.extend_in(&parent_table, &child, &est);
        let delta = bench_quick(&format!("simulate/delta-replay/{name} ({ops} ops)"), || {
            black_box(simulate_delta(
                &parent,
                &log,
                &child,
                &frontier,
                &child_table,
                SimOptions::default(),
                &mut NoRecord,
                &mut ws,
            ));
        });

        let ops_per_ms = ops as f64 / (tabled.mean_ns / 1e6);
        println!(
            "  -> {ops_per_ms:.0} simulated ops/ms cost-table ({:.2}x vs fresh-alloc, {:.2}x vs reused-ws); delta replay {:.2}x vs cost-table",
            fresh.mean_ns / tabled.mean_ns,
            reused.mean_ns / tabled.mean_ns,
            tabled.mean_ns / delta.mean_ns,
        );
    }

    // Hi-fi execution (Table 2's "real run") — noisy, multi-iteration.
    let g = build(&ModelSpec { kind: ModelKind::Rnnlm, batch: 16, depth_scale: 0.25 }, 12);
    bench_quick("hifi_execute/rnnlm-fast x5 iters", || {
        black_box(execute_real(&g, &device, &cluster, &HifiOptions::default()));
    });
}
