//! L3 hot path: cost-model simulation throughput (`Cost(H)` is called
//! thousands of times per search — DESIGN.md §8 target ≥ 10k simulated
//! ops/ms).

use disco::device::DeviceModel;
use disco::estimator::CostEstimator;
use disco::models::{build, ModelKind, ModelSpec};
use disco::network::Cluster;
use disco::profiler::profile;
use disco::sim::hifi::{execute_real, HifiOptions};
use disco::sim::{simulate, SimOptions};
use disco::util::timer::{bench_quick, black_box};

fn main() {
    let cluster = Cluster::cluster_a();
    let device = DeviceModel::gtx1080ti();

    for (name, spec) in [
        ("rnnlm-fast", ModelSpec { kind: ModelKind::Rnnlm, batch: 16, depth_scale: 0.25 }),
        ("transformer-full", ModelSpec::transformer_base()),
        ("bert-full", ModelSpec::bert_base()),
    ] {
        let g = build(&spec, cluster.num_devices());
        let prof = profile(&g, &device, &cluster, 2, 1);
        let est = CostEstimator::oracle(&prof, &device);
        let ops = g.live_count();
        let r = bench_quick(&format!("simulate/{name} ({ops} ops)"), || {
            black_box(simulate(&g, &est, SimOptions::default()));
        });
        let ops_per_ms = ops as f64 / (r.mean_ns / 1e6);
        println!("  -> {ops_per_ms:.0} simulated ops/ms");
    }

    // Hi-fi execution (Table 2's "real run") — noisy, multi-iteration.
    let g = build(&ModelSpec { kind: ModelKind::Rnnlm, batch: 16, depth_scale: 0.25 }, 12);
    bench_quick("hifi_execute/rnnlm-fast x5 iters", || {
        black_box(execute_real(&g, &device, &cluster, &HifiOptions::default()));
    });
}
