//! L3 hot path: fusion rewrite application + candidate enumeration +
//! candidate cloning (the per-search-step costs).

use disco::fusion::{self, FusionKind};
use disco::models::{build, ModelSpec};
use disco::util::rng::Rng;
use disco::util::timer::{bench_quick, black_box};

fn main() {
    let g = build(&ModelSpec::transformer_base(), 12);
    println!("transformer-full: {} live nodes", g.live_count());

    bench_quick("clone/transformer-full", || {
        black_box(g.clone());
    });

    bench_quick("op_fusion_candidates/transformer-full", || {
        black_box(fusion::op_fusion_candidates(&g));
    });

    let cands = fusion::op_fusion_candidates(&g);
    let mut rng = Rng::new(7);
    bench_quick("fuse_ops(nondup)/transformer-full", || {
        let mut h = g.clone();
        let (p, s) = cands[rng.gen_range(cands.len())];
        let _ = black_box(fusion::fuse_ops(&mut h, p, s, FusionKind::NonDuplicate));
    });

    bench_quick("ar_neighbors/transformer-full", || {
        let ars = g.allreduces();
        black_box(fusion::ar_neighbors(&g, ars[ars.len() / 2]));
    });

    bench_quick("fingerprint/transformer-full", || {
        black_box(g.fingerprint());
    });

    bench_quick("to_json/transformer-full", || {
        black_box(g.to_json());
    });
}
