//! L3 end-to-end: backtracking-search throughput (evals/s) and one
//! fixed-budget search per representative model — the engineering numbers
//! behind Tables 3/4's search-time column.

use disco::device::DeviceModel;
use disco::estimator::CostEstimator;
use disco::models::{build, ModelKind, ModelSpec};
use disco::network::Cluster;
use disco::profiler::profile;
use disco::search::{backtracking_search, SearchConfig};
use disco::util::timer::black_box;

fn main() {
    let cluster = Cluster::cluster_a();
    let device = DeviceModel::gtx1080ti();

    for (name, spec) in [
        ("rnnlm-fast", ModelSpec { kind: ModelKind::Rnnlm, batch: 16, depth_scale: 0.25 }),
        ("resnet50-fast", ModelSpec { kind: ModelKind::ResNet50, batch: 8, depth_scale: 0.25 }),
        ("transformer-full", ModelSpec::transformer_base()),
    ] {
        let g = build(&spec, cluster.num_devices());
        let prof = profile(&g, &device, &cluster, 2, 1);
        let est = CostEstimator::oracle(&prof, &device);
        let cfg = SearchConfig { unchanged_limit: 200, seed: 3, ..Default::default() };
        let start = std::time::Instant::now();
        let r = backtracking_search(&g, &est, &cfg);
        let dt = start.elapsed().as_secs_f64();
        let (hits, misses) = est.cache_stats();
        println!(
            "search/{name:<18} {:>6} evals in {dt:>6.2}s = {:>7.0} evals/s   {:.2} -> {:.2} ms   cache {hits}h/{misses}m",
            r.evals,
            r.evals as f64 / dt,
            r.initial_cost_ms,
            r.best_cost_ms,
        );
        black_box(r);
    }
}
