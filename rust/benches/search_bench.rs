//! L3 end-to-end: backtracking-search throughput (evals/s) and one
//! fixed-budget search per representative model — the engineering numbers
//! behind Tables 3/4's search-time column — plus the hot-path A/B record:
//! the same search run with the pre-refactor engine behavior (eager
//! full-clone arena, fresh scratch per eval, candidate re-enumeration per
//! mutation, serial eval) versus the current engine (delta-encoded
//! candidates, reused workspaces, incremental candidate pool, parallel
//! eval). Writes `BENCH_search.json` at the repo root.

use disco::bench::{write_search_perf_record, BenchOptions, Scale};
use disco::device::DeviceModel;
use disco::estimator::CostEstimator;
use disco::models::{build, ModelKind, ModelSpec};
use disco::network::Cluster;
use disco::profiler::profile;
use disco::search::{backtracking_search, SearchConfig};
use disco::util::timer::black_box;

fn main() {
    let cluster = Cluster::cluster_a();
    let device = DeviceModel::gtx1080ti();

    for (name, spec) in [
        ("rnnlm-fast", ModelSpec { kind: ModelKind::Rnnlm, batch: 16, depth_scale: 0.25 }),
        ("resnet50-fast", ModelSpec { kind: ModelKind::ResNet50, batch: 8, depth_scale: 0.25 }),
        ("transformer-full", ModelSpec::transformer_base()),
    ] {
        let g = build(&spec, cluster.num_devices());
        let prof = profile(&g, &device, &cluster, 2, 1);
        let est = CostEstimator::oracle(&prof, &device);
        let cfg = SearchConfig { unchanged_limit: 200, seed: 3, ..Default::default() };
        let start = std::time::Instant::now();
        let r = backtracking_search(&g, &est, &cfg);
        let dt = start.elapsed().as_secs_f64();
        let (hits, misses) = est.cache_stats();
        println!(
            "search/{name:<18} {:>6} evals in {dt:>6.2}s = {:>7.0} evals/s   {:.2} -> {:.2} ms   arena peak {:.2} MB   cache {hits}h/{misses}m",
            r.evals,
            r.evals as f64 / dt,
            r.initial_cost_ms,
            r.best_cost_ms,
            r.peak_arena_bytes as f64 / 1e6,
        );
        black_box(r);
    }

    // Hot-path A/B on the acceptance workload (transformer_base, 12
    // workers) → BENCH_search.json at the repo root. Three arms: PR-0
    // "before", PR-1 "after" (allocation-free, full sims) and "delta"
    // (cost tables + checkpointed delta simulation, current default).
    let opts = BenchOptions { scale: Scale::Full, ..Default::default() };
    match write_search_perf_record(&opts) {
        Ok((record, path)) => {
            for (tag, m) in [
                ("before", &record.before),
                ("after", &record.after),
                ("delta", &record.delta),
            ] {
                println!(
                    "hotpath/{tag:<7} {:>6} evals (+{} resims) in {:>6.2}s = {:>7.0} evals/s   arena peak {:.2} MB   best {:.2} ms   cache {}h/{}m/{}e",
                    m.evals,
                    m.resims,
                    m.seconds,
                    m.evals_per_sec,
                    m.peak_arena_bytes as f64 / 1e6,
                    m.best_cost_ms,
                    m.cache_hits,
                    m.cache_misses,
                    m.cache_evictions,
                );
            }
            println!(
                "hotpath ratios: after/before {:.2}x evals/s, delta/after {:.2}x evals/s, {:.2}x smaller arena  -> {}",
                record.throughput_ratio(),
                record.delta_ratio(),
                record.arena_ratio(),
                path.display()
            );
        }
        Err(e) => eprintln!("failed to write BENCH_search.json: {e}"),
    }
}
