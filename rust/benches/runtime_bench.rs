//! Runtime hot paths: GNN batch prediction latency (the search-time
//! estimator query) and LM train-step latency (the enactment workload).
//! Runs on the default interpreter backend (bootstrapping artifacts if
//! needed); skips quietly only when the stubbed PJRT backend is forced.

use disco::estimator::AnalyticalFused;
use disco::graph::{FusedGroup, OpKind, OrigOp};
use disco::runtime::gnn::GnnPredictor;
use disco::runtime::trainer::Corpus;
use disco::runtime::{lit_f32, lit_i32, Manifest, Runtime};
use disco::util::timer::{bench_quick, black_box};

fn chain(n: usize) -> FusedGroup {
    FusedGroup {
        ops: (0..n)
            .map(|i| OrigOp {
                orig_id: i,
                kind: OpKind::Mul,
                flops: 1e6,
                bytes_in: 4e5,
                bytes_out: 4e5,
                time_ms: 0.02,
                duplicated: false,
            })
            .collect(),
        edges: (1..n).map(|i| (i - 1, i)).collect(),
    }
}

fn main() {
    let dir = Manifest::default_dir();
    let rt = match Runtime::new(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP runtime_bench: {e:#} (PJRT backend is stubbed offline)");
            return;
        }
    };

    // GNN predictor latency at various batch fill levels.
    let fallback = AnalyticalFused { launch_ms: 0.005, bw_bytes_per_ms: 4.8e8 };
    let pred = GnnPredictor::load(&rt, fallback).unwrap();
    for fill in [1usize, 8, 64] {
        let items: Vec<_> = (0..fill).map(|i| (chain(2 + i % 30), 4e5, 4e5)).collect();
        bench_quick(&format!("gnn_predict/batch_fill={fill}"), || {
            black_box(pred.predict(&items).unwrap());
        });
    }

    // LM gradient step latency (one worker).
    let grads = rt.load("lm_grads").unwrap();
    let lm = rt.manifest.raw.get("lm");
    let flat_len = lm.get("flat_len").as_usize().unwrap();
    let batch = lm.get("batch").as_usize().unwrap();
    let seq = lm.get("seq").as_usize().unwrap();
    let params = rt.manifest.load_f32(lm.get("params").as_str().unwrap()).unwrap();
    let corpus = Corpus::synthetic(1 << 14, 1);
    let tokens = corpus.batch(batch, seq, 0, 1, 0);
    bench_quick("lm_grads/one_step", || {
        black_box(
            grads
                .run(&[
                    lit_f32(&params, &[flat_len]).unwrap(),
                    lit_i32(&tokens, &[batch, seq + 1]).unwrap(),
                ])
                .unwrap(),
        );
    });
}
