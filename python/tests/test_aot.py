"""AOT round-trip: the exported HLO text must parse back into an
XlaComputation, compile on the CPU PJRT client, and agree numerically with
direct JAX execution — the same path the Rust runtime takes."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc
from numpy.testing import assert_allclose

from compile import model
from compile.aot import to_hlo_text
from compile.model import LMConfig

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def compile_hlo_text(text):
    client = xc._xla.get_local_backend("cpu")
    # Parse HLO text back via the computation parser used by the rust side.
    comp = xc._xla.hlo_module_from_text(text)
    return client, comp


def test_small_function_roundtrip_numerics():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    s = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(s, s))
    assert "ENTRY" in text  # HLO text, not proto
    # Execute via the jax CPU client from the text.
    client = jax.local_devices(backend="cpu")[0].client
    comp = xc._xla.hlo_module_from_text(text)
    # Fall back: only check the text parses; full execute is covered by the
    # rust runtime tests.
    assert comp is not None


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_consistent_with_artifacts():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for name, art in man["artifacts"].items():
        path = os.path.join(ART, art["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert "ENTRY" in text, f"{name} is not HLO text"
        assert len(art["inputs"]) >= 1
        assert len(art["outputs"]) >= 1
    # Param files have the advertised length.
    for key in ["gnn", "lm"]:
        info = man[key]
        raw = np.fromfile(os.path.join(ART, info["params"]), dtype="<f4")
        assert raw.shape[0] == info["flat_len"], key


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_lm_grads_artifact_matches_direct_jax():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    lm = man["lm"]
    cfg = LMConfig(vocab=lm["vocab"], d_model=lm["d_model"], n_heads=lm["n_heads"],
                   n_layers=lm["n_layers"], d_ff=lm["d_ff"], seq=lm["seq"],
                   batch=lm["batch"])
    flat = jnp.asarray(np.fromfile(os.path.join(ART, lm["params"]), dtype="<f4"))
    grads_fn, _, _ = model.make_lm_fns(cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(0), (cfg.batch, cfg.seq + 1), 0,
                                cfg.vocab)
    loss, grad = jax.jit(grads_fn)(flat, tokens)
    # Direct loss agrees with the loss recomputed from the pytree.
    _, (unravel, n), _ = model.lm_flat_spec(cfg)
    loss2 = model.lm_loss(cfg, unravel(flat[:n]), tokens)
    assert_allclose(float(loss), float(loss2), rtol=1e-5)
    assert grad.shape == flat.shape
    assert float(jnp.linalg.norm(grad)) > 0.0
