"""Kernel-vs-reference correctness: hypothesis sweeps over shapes/dtypes,
assert_allclose against the pure-jnp oracles in kernels/ref.py.

This is the CORE correctness signal for Layer 1.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import adam_update, causal_attention, gat_attention
from compile.kernels.adam import BLOCK
from compile.kernels.ref import (
    adam_update_ref,
    causal_attention_ref,
    gat_attention_ref,
)

SETTINGS = dict(max_examples=12, deadline=None)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# GAT kernel
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    n=st.sampled_from([4, 8, 16, 64]),
    d=st.sampled_from([8, 32, 64]),
    heads=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**20),
)
def test_gat_matches_ref(b, n, d, heads, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    h = rand(ks[0], (b, n, d))
    adj = (jax.random.uniform(ks[1], (b, n, n)) > 0.5).astype(jnp.float32)
    adj = adj.at[:, jnp.arange(n), jnp.arange(n)].set(1.0)
    w_src = rand(ks[2], (d, heads), scale=0.1)
    w_dst = rand(ks[3], (d, heads), scale=0.1)
    out = gat_attention(h, adj, w_src, w_dst)
    ref = jnp.stack([gat_attention_ref(h[i], adj[i], w_src, w_dst) for i in range(b)])
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_gat_padded_nodes_produce_zeros():
    # Padded rows: zero features, zero adjacency (no self loop).
    b, n, d, heads = 2, 8, 16, 2
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    h = rand(ks[0], (b, n, d))
    h = h.at[:, 4:, :].set(0.0)
    adj = jnp.zeros((b, n, n))
    adj = adj.at[:, :4, :4].set(1.0)
    out = gat_attention(h, adj, rand(ks[1], (d, heads)), rand(ks[2], (d, heads)))
    # Rows 4.. aggregate nothing: all-masked softmax denominators are 0.
    assert_allclose(np.asarray(out[:, 4:, :]), 0.0, atol=1e-6)


def test_gat_self_loop_only_is_identity_mean():
    # With adjacency = I, each node attends only to itself: out == h.
    b, n, d, heads = 1, 6, 8, 3
    h = rand(jax.random.PRNGKey(1), (b, n, d))
    adj = jnp.eye(n)[None]
    out = gat_attention(h, adj, jnp.zeros((d, heads)), jnp.zeros((d, heads)))
    assert_allclose(np.asarray(out), np.asarray(h), rtol=1e-5, atol=1e-6)


def test_gat_gradients_flow():
    b, n, d, heads = 2, 8, 16, 2
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    h = rand(ks[0], (b, n, d))
    adj = jnp.ones((b, n, n))
    w_src = rand(ks[1], (d, heads), scale=0.1)
    w_dst = rand(ks[2], (d, heads), scale=0.1)

    def f(h_, ws, wd):
        return jnp.sum(gat_attention(h_, adj, ws, wd) ** 2)

    def f_ref(h_, ws, wd):
        out = jnp.stack([gat_attention_ref(h_[i], adj[i], ws, wd) for i in range(b)])
        return jnp.sum(out**2)

    g = jax.grad(f, argnums=(0, 1, 2))(h, w_src, w_dst)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(h, w_src, w_dst)
    for a, bb in zip(g, gr):
        assert_allclose(np.asarray(a), np.asarray(bb), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Causal attention kernel
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([4, 16, 64, 128]),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**20),
)
def test_attention_matches_ref(b, h, s, d, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = (rand(ks[i], (b, h, s, d)) for i in range(3))
    assert_allclose(
        np.asarray(causal_attention(q, k, v)),
        np.asarray(causal_attention_ref(q, k, v)),
        rtol=2e-5,
        atol=2e-5,
    )


def test_attention_is_causal():
    # Output at position t must not depend on inputs at positions > t.
    b, h, s, d = 1, 1, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q, k, v = (rand(ks[i], (b, h, s, d)) for i in range(3))
    out1 = causal_attention(q, k, v)
    k2 = k.at[:, :, 5:, :].set(99.0)
    v2 = v.at[:, :, 5:, :].set(-99.0)
    out2 = causal_attention(q, k2, v2)
    assert_allclose(np.asarray(out1[:, :, :5]), np.asarray(out2[:, :, :5]), rtol=1e-5)
    assert not np.allclose(np.asarray(out1[:, :, 5:]), np.asarray(out2[:, :, 5:]))


def test_attention_first_token_is_v0():
    b, h, s, d = 1, 2, 6, 4
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q, k, v = (rand(ks[i], (b, h, s, d)) for i in range(3))
    out = causal_attention(q, k, v)
    assert_allclose(np.asarray(out[:, :, 0]), np.asarray(v[:, :, 0]), rtol=1e-5)


def test_attention_bf16_runs():
    b, h, s, d = 1, 1, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q, k, v = (rand(ks[i], (b, h, s, d), dtype=jnp.bfloat16) for i in range(3))
    out = causal_attention(q, k, v)
    ref = causal_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    assert_allclose(
        np.asarray(out.astype(jnp.float32)), np.asarray(ref), rtol=5e-2, atol=5e-2
    )


# ---------------------------------------------------------------------------
# Adam kernel
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    nblocks=st.integers(1, 4),
    t=st.integers(1, 1000),
    lr=st.sampled_from([1e-4, 1e-3, 1e-2]),
    seed=st.integers(0, 2**20),
)
def test_adam_matches_ref(nblocks, t, lr, seed):
    n = nblocks * BLOCK
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    p, g, m, v = (rand(ks[i], (n,)) for i in range(4))
    v = jnp.abs(v)
    pn, mn, vn = adam_update(p, g, m, v, jnp.array([float(t)]), lr=lr)
    pr, mr, vr = adam_update_ref(p, g, m, v, float(t), lr=lr)
    # f32 pow(b, t) in the kernel vs f64 promotion in the ref: allow a
    # few ULP of drift in the bias-corrected moments.
    assert_allclose(np.asarray(pn), np.asarray(pr), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(mn), np.asarray(mr), rtol=1e-5, atol=1e-8)
    assert_allclose(np.asarray(vn), np.asarray(vr), rtol=1e-5, atol=1e-8)


def test_adam_zero_grad_padding_fixed_point():
    # Zero-padded tail (g = m = v = 0) must leave p unchanged.
    n = BLOCK
    p = jnp.ones((n,))
    z = jnp.zeros((n,))
    pn, mn, vn = adam_update(p, z, z, z, jnp.array([3.0]))
    assert_allclose(np.asarray(pn), np.asarray(p), atol=1e-7)
    assert_allclose(np.asarray(mn), 0.0)
    assert_allclose(np.asarray(vn), 0.0)


def test_adam_rejects_unaligned():
    n = BLOCK + 1
    z = jnp.zeros((n,))
    with pytest.raises(AssertionError):
        adam_update(z, z, z, z, jnp.array([1.0]))


def test_adam_descends_quadratic():
    # Minimizing 0.5*||p||^2: repeated fused-Adam steps shrink the norm.
    n = BLOCK
    p = rand(jax.random.PRNGKey(11), (n,))
    m = jnp.zeros((n,))
    v = jnp.zeros((n,))
    norm0 = float(jnp.linalg.norm(p))
    for t in range(1, 51):
        g = p  # grad of 0.5 ||p||^2
        p, m, v = adam_update(p, g, m, v, jnp.array([float(t)]), lr=1e-2)
    assert float(jnp.linalg.norm(p)) < norm0 * 0.8
