"""L2 model checks: GNN estimator shapes/learning, transformer LM
shapes/learning, and flat-parameter round-trips."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model
from compile.model import LMConfig


def synth_batch(key, b=8, n=16):
    """A toy supervised batch: label = total 'time' feature mass, so the
    GNN has an easy learnable signal."""
    ks = jax.random.split(key, 3)
    feats = jnp.zeros((b, n, model.FEAT_DIM))
    kinds = jax.random.randint(ks[0], (b, n), 0, model.N_OP_KINDS)
    feats = feats.at[jnp.arange(b)[:, None], jnp.arange(n)[None, :], kinds].set(1.0)
    times = jax.random.uniform(ks[1], (b, n)) * 0.5
    feats = feats.at[:, :, model.N_OP_KINDS].set(times)
    adj = (jax.random.uniform(ks[2], (b, n, n)) > 0.7).astype(jnp.float32)
    adj = adj.at[:, jnp.arange(n), jnp.arange(n)].set(1.0)
    adj = jnp.maximum(adj, jnp.transpose(adj, (0, 2, 1)))
    mask = jnp.ones((b, n))
    target = jnp.sum(times, axis=1)
    return feats, adj, mask, target


def test_gnn_forward_shape_and_positivity():
    params = model.init_gnn_params(jax.random.PRNGKey(0))
    feats, adj, mask, _ = synth_batch(jax.random.PRNGKey(1))
    pred = model.gnn_forward(params, feats, adj, mask)
    assert pred.shape == (8,)
    assert bool(jnp.all(pred >= 0.0))


def test_gnn_padding_invariance():
    # Adding padded (masked-out) nodes must not change predictions.
    params = model.init_gnn_params(jax.random.PRNGKey(0))
    feats, adj, mask, _ = synth_batch(jax.random.PRNGKey(2), b=4, n=8)
    pred_small = model.gnn_forward(params, feats, adj, mask)
    n2 = 16
    feats2 = jnp.zeros((4, n2, model.FEAT_DIM)).at[:, :8].set(feats)
    adj2 = jnp.zeros((4, n2, n2)).at[:, :8, :8].set(adj)
    mask2 = jnp.zeros((4, n2)).at[:, :8].set(1.0)
    pred_big = model.gnn_forward(params, feats2, adj2, mask2)
    assert_allclose(np.asarray(pred_small), np.asarray(pred_big), rtol=1e-4, atol=1e-5)


def test_gnn_learns_synthetic_signal():
    _, (unravel, n), flat0 = model.gnn_flat_spec()
    _, train = model.make_gnn_fns()
    train = jax.jit(train)
    feats, adj, mask, target = synth_batch(jax.random.PRNGKey(3), b=model.GNN_BATCH, n=model.MAX_NODES)
    flat = flat0
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    losses = []
    for t in range(1, 41):
        loss, flat, m, v = train(flat, m, v, jnp.array([float(t)]), feats, adj, mask, target)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, losses[::10]


def test_lm_forward_shapes():
    cfg = LMConfig()
    params = model.init_lm_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.zeros((2, cfg.seq), dtype=jnp.int32)
    logits = model.lm_forward(cfg, params, tokens)
    assert logits.shape == (2, cfg.seq, cfg.vocab)


def test_lm_loss_near_uniform_at_init():
    cfg = LMConfig()
    params = model.init_lm_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, cfg.seq + 1), 0, cfg.vocab)
    loss = model.lm_loss(cfg, params, tokens)
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.5


def test_lm_trains_on_repetitive_data():
    cfg = LMConfig(d_model=64, n_layers=1, d_ff=128, seq=32, batch=8)
    _, _, flat = model.lm_flat_spec(cfg)
    grads, adam, _ = model.make_lm_fns(cfg)
    grads = jax.jit(grads)
    adam = jax.jit(adam)
    # Periodic token stream: trivially predictable.
    base = jnp.arange(cfg.seq + 1, dtype=jnp.int32) % 7
    tokens = jnp.tile(base[None, :], (cfg.batch, 1))
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    first = None
    for t in range(1, 151):
        loss, g = grads(flat, tokens)
        flat, m, v = adam(flat, g, m, v, jnp.array([float(t)]))
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.5, (first, float(loss))


def test_flat_roundtrip_lengths():
    plen, (unravel, n), flat = model.gnn_flat_spec()
    assert flat.shape == (plen,)
    assert plen % 1024 == 0
    assert n <= plen
    cfg = LMConfig()
    plen2, (_, n2), flat2 = model.lm_flat_spec(cfg)
    assert flat2.shape == (plen2,)
    assert plen2 % 1024 == 0
    assert n2 <= plen2
