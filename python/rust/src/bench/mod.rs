//! Benchmark harness library (tables/figures; being populated).
