"""Layer-2 JAX models (build-time only).

Two computations, both calling the Layer-1 Pallas kernels so that they
lower into the same HLO modules the Rust runtime executes:

1. The **GNN Fused-Op Estimator** (paper §4.3): 6 graph-attention layers
   (the ``gat_attention`` Pallas kernel) encode a fused-op subgraph, a
   masked sum pools node embeddings into the fused-op embedding (eq. (2)),
   and a 3-layer regression MLP predicts execution time. Trained with MSE
   in log space.

2. A small **transformer LM train step** — the end-to-end workload the
   distributed-enactment example trains for real. The attention uses the
   ``causal_attention`` Pallas kernel; the optimizer uses the fused
   ``adam_update`` kernel. Gradient computation and the optimizer step are
   exported as *separate* artifacts so the Rust ring-AllReduce can average
   gradients between them (synchronous data parallelism).

Parameters cross the Rust boundary as one flat f32 vector (padded to the
Adam kernel's block size); the pytree structure lives only here.
"""

import functools

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels import adam_update, causal_attention, gat_attention
from .kernels.adam import BLOCK as ADAM_BLOCK

# ---------------------------------------------------------------------------
# Feature encoding contract with rust/src/runtime/gnn.rs — keep in sync.
# ---------------------------------------------------------------------------

N_OP_KINDS = 40          # graph::OpKind::ALL
N_SCALAR_FEATS = 9       # per-op: 0.2*ln(time_ms+1e-5), 0.2*ln(MB_in+1e-4),
                         # 0.2*ln(MB_out+1e-4), 0.2*ln(GFLOP+1e-5), dup flag;
                         # broadcast: 0.2*ln(fused-node boundary MB in/out)
                         # (bandwidth-bound fused kernels are priced by
                         # boundary traffic, which no single member knows);
                         # structural: has-internal-consumer,
                         # has-internal-producer flags
FEAT_DIM = N_OP_KINDS + N_SCALAR_FEATS
MAX_NODES = 64           # fused groups larger than this use the analytical
                         # fallback on the Rust side
GNN_BATCH = 64           # static batch of the AOT artifacts (search-time
                         # queries arrive in small bursts; a modest batch
                         # keeps per-call CPU latency low)

GNN_HIDDEN = 64
GNN_HEADS = 4
GNN_LAYERS = 6           # paper §5.2: 6 graph conv layers
GNN_MLP = (64, 32, 1)    # 3 dense regression layers
GNN_LR = 2e-3


def init_gnn_params(key):
    """Initialize the estimator's parameter pytree."""
    params = {}
    k_in, key = jax.random.split(key)
    params["w_in"] = jax.random.normal(k_in, (FEAT_DIM, GNN_HIDDEN)) * (
        1.0 / jnp.sqrt(FEAT_DIM)
    )
    params["b_in"] = jnp.zeros((GNN_HIDDEN,))
    for l in range(GNN_LAYERS):
        k1, k2, k3, key = jax.random.split(key, 4)
        params[f"gat{l}_src"] = jax.random.normal(k1, (GNN_HIDDEN, GNN_HEADS)) * 0.1
        params[f"gat{l}_dst"] = jax.random.normal(k2, (GNN_HIDDEN, GNN_HEADS)) * 0.1
        params[f"gat{l}_w"] = jax.random.normal(k3, (GNN_HIDDEN, GNN_HIDDEN)) * (
            1.0 / jnp.sqrt(GNN_HIDDEN)
        )
        params[f"gat{l}_b"] = jnp.zeros((GNN_HIDDEN,))
    dim = GNN_HIDDEN
    for i, out in enumerate(GNN_MLP):
        k1, key = jax.random.split(key)
        params[f"mlp{i}_w"] = jax.random.normal(k1, (dim, out)) * (1.0 / jnp.sqrt(dim))
        params[f"mlp{i}_b"] = jnp.zeros((out,))
        dim = out
    return params


def _gnn_forward_log(params, feats, adj, mask):
    """Regression output y = log1p(time_ms) for fused-op subgraphs.

    Args:
      params: pytree from :func:`init_gnn_params`.
      feats: [B, N, FEAT_DIM] node features (padded rows zero).
      adj:   [B, N, N] adjacency in *both* directions + self loops for live
             nodes (message passing over data deps, paper eq. (1)).
      mask:  [B, N] 1.0 for live nodes.

    Returns:
      [B] predicted execution time in ms (positive).
    """
    h = jnp.tanh(feats @ params["w_in"] + params["b_in"])
    h = h * mask[:, :, None]
    for l in range(GNN_LAYERS):
        agg = gat_attention(h, adj, params[f"gat{l}_src"], params[f"gat{l}_dst"])
        h2 = jnp.tanh(agg @ params[f"gat{l}_w"] + params[f"gat{l}_b"])
        h = (h + h2) * mask[:, :, None]  # residual + re-mask padding
    # Fused-op embedding: masked sum over member ops (paper eq. (2)).
    g = jnp.sum(h * mask[:, :, None], axis=1)
    x = g
    for i in range(len(GNN_MLP)):
        x = x @ params[f"mlp{i}_w"] + params[f"mlp{i}_b"]
        if i + 1 < len(GNN_MLP):
            x = jnp.maximum(x, 0.0)
    return x[:, 0]  # y = ln(time_ms), unconstrained


def gnn_forward(params, feats, adj, mask):
    """Predicted execution time in ms (positive)."""
    return jnp.exp(_gnn_forward_log(params, feats, adj, mask))


def gnn_loss(params, feats, adj, mask, target_ms):
    """MSE in ln space: |Δln t| IS the relative error, so a 20 µs op and a
    30 ms op contribute equally — the paper's error metric (|pred−real|/
    real) is exactly what this optimizes."""
    y = _gnn_forward_log(params, feats, adj, mask)
    return jnp.mean((y - jnp.log(jnp.maximum(target_ms, 1e-5))) ** 2)


# --- flat-vector packaging --------------------------------------------------


def _pad_to_block(flat):
    n = flat.shape[0]
    pad = (-n) % ADAM_BLOCK
    return jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)]), n


def gnn_flat_spec(key=None):
    """(padded_len, unravel, initial_flat) for the estimator parameters."""
    params = init_gnn_params(key if key is not None else jax.random.PRNGKey(0))
    flat, unravel = ravel_pytree(params)
    padded, n = _pad_to_block(flat)
    return padded.shape[0], (unravel, n), padded


def make_gnn_fns():
    """Flat-parameter entry points for AOT export."""
    _, (unravel, n), _ = gnn_flat_spec()

    def infer(flat, feats, adj, mask):
        params = unravel(flat[:n])
        return (gnn_forward(params, feats, adj, mask),)

    def train_step(flat, m, v, t, feats, adj, mask, target_ms):
        def loss_flat(f):
            return gnn_loss(unravel(f[:n]), feats, adj, mask, target_ms)

        loss, grad = jax.value_and_grad(loss_flat)(flat)
        p2, m2, v2 = adam_update(flat, grad, m, v, t, lr=GNN_LR)
        return loss, p2, m2, v2

    return infer, train_step


# ---------------------------------------------------------------------------
# Transformer language model (the end-to-end training workload).
# ---------------------------------------------------------------------------


class LMConfig:
    """Static transformer-LM configuration (shapes are baked into the AOT
    artifacts). The default is CPU-friendly; scale up via aot.py flags."""

    def __init__(self, vocab=256, d_model=128, n_heads=4, n_layers=2, d_ff=512,
                 seq=64, batch=8, lr=3e-4):
        self.vocab = vocab
        self.d_model = d_model
        self.n_heads = n_heads
        self.n_layers = n_layers
        self.d_ff = d_ff
        self.seq = seq
        self.batch = batch
        self.lr = lr

    def describe(self):
        return (f"vocab={self.vocab} d={self.d_model} h={self.n_heads} "
                f"L={self.n_layers} ff={self.d_ff} s={self.seq} b={self.batch}")


def init_lm_params(cfg, key):
    params = {}
    k, key = jax.random.split(key)
    params["embed"] = jax.random.normal(k, (cfg.vocab, cfg.d_model)) * 0.02
    for l in range(cfg.n_layers):
        for name, shape in [
            ("wq", (cfg.d_model, cfg.d_model)),
            ("wk", (cfg.d_model, cfg.d_model)),
            ("wv", (cfg.d_model, cfg.d_model)),
            ("wo", (cfg.d_model, cfg.d_model)),
            ("ff1", (cfg.d_model, cfg.d_ff)),
            ("ff2", (cfg.d_ff, cfg.d_model)),
        ]:
            k, key = jax.random.split(key)
            params[f"l{l}_{name}"] = jax.random.normal(k, shape) * (
                1.0 / jnp.sqrt(shape[0])
            )
        params[f"l{l}_ln1"] = jnp.ones((cfg.d_model,))
        params[f"l{l}_ln1b"] = jnp.zeros((cfg.d_model,))
        params[f"l{l}_ln2"] = jnp.ones((cfg.d_model,))
        params[f"l{l}_ln2b"] = jnp.zeros((cfg.d_model,))
    params["ln_f"] = jnp.ones((cfg.d_model,))
    params["ln_fb"] = jnp.zeros((cfg.d_model,))
    k, key = jax.random.split(key)
    params["head"] = jax.random.normal(k, (cfg.d_model, cfg.vocab)) * 0.02
    return params


def _layer_norm(x, scale, bias):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * scale + bias


def lm_forward(cfg, params, tokens):
    """Next-token logits. tokens: [B, S] int32 → [B, S, vocab]."""
    b, s = tokens.shape
    h = params["embed"][tokens]  # [B, S, D]
    # Sinusoid-free learned-position-free: add a fixed ramp (cheap, fine at
    # this scale and keeps the parameter story simple).
    pos = jnp.arange(s)[None, :, None] / float(s)
    h = h + 0.1 * pos
    dh = cfg.d_model // cfg.n_heads
    for l in range(cfg.n_layers):
        x = _layer_norm(h, params[f"l{l}_ln1"], params[f"l{l}_ln1b"])
        q = (x @ params[f"l{l}_wq"]).reshape(b, s, cfg.n_heads, dh).transpose(0, 2, 1, 3)
        k = (x @ params[f"l{l}_wk"]).reshape(b, s, cfg.n_heads, dh).transpose(0, 2, 1, 3)
        v = (x @ params[f"l{l}_wv"]).reshape(b, s, cfg.n_heads, dh).transpose(0, 2, 1, 3)
        ctx = causal_attention(q, k, v)  # Pallas kernel
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        h = h + ctx @ params[f"l{l}_wo"]
        x = _layer_norm(h, params[f"l{l}_ln2"], params[f"l{l}_ln2b"])
        h = h + jnp.maximum(x @ params[f"l{l}_ff1"], 0.0) @ params[f"l{l}_ff2"]
    h = _layer_norm(h, params["ln_f"], params["ln_fb"])
    return h @ params["head"]


def lm_loss(cfg, params, tokens):
    """Causal LM loss on a [B, S+1] token window."""
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    logits = lm_forward(cfg, params, inputs)
    logits = logits - jax.scipy.special.logsumexp(logits, axis=-1, keepdims=True)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def lm_flat_spec(cfg, key=None):
    params = init_lm_params(cfg, key if key is not None else jax.random.PRNGKey(42))
    flat, unravel = ravel_pytree(params)
    padded, n = _pad_to_block(flat)
    return padded.shape[0], (unravel, n), padded


def make_lm_fns(cfg):
    """(grads_fn, adam_fn, eval_fn) over flat parameters, for AOT export.

    * grads:  (flat, tokens[B,S+1] i32) → (loss, grads_flat) — run per
      worker; gradients are ring-AllReduced in Rust between the two calls.
    * adam:   (flat, grads, m, v, t) → (flat', m', v') — fused Pallas Adam.
    * eval:   (flat, tokens) → (loss,) — held-out evaluation.
    """
    _, (unravel, n), _ = lm_flat_spec(cfg)

    def grads(flat, tokens):
        def loss_flat(f):
            return lm_loss(cfg, unravel(f[:n]), tokens)

        loss, grad = jax.value_and_grad(loss_flat)(flat)
        return loss, grad

    def adam(flat, grad, m, v, t):
        return adam_update(flat, grad, m, v, t, lr=cfg.lr)

    def evaluate(flat, tokens):
        return (lm_loss(cfg, unravel(flat[:n]), tokens),)

    return grads, adam, evaluate
