"""Pallas kernel: masked multi-head graph-attention aggregation.

The compute hot-spot of the GNN Fused-Op Estimator (paper §4.3.1 eq. (1)):
per-head attention scores between every pair of connected ops, masked
softmax over neighbours, and feature aggregation — O(N²·H + N²·D) per
fused-op subgraph.

TPU mapping (DESIGN.md §3): the grid iterates over the batch of subgraphs;
each grid step holds one graph's [N, D] features and [N, N] adjacency in
VMEM (N = 64, D ≤ 128 → ≤ 96 KiB — far under the ~16 MiB VMEM budget) and
drives the MXU with the two [N, D] x [D, H] score matmuls and the [N·H, N]
x [N, D] aggregation contraction. The HBM↔VMEM schedule is expressed with
BlockSpec: one graph per block, weights broadcast to every step.

``interpret=True`` everywhere — CPU PJRT cannot run Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import LEAKY_SLOPE


def _gat_kernel(h_ref, adj_ref, wsrc_ref, wdst_ref, o_ref):
    """One graph per grid step; block shapes carry the [N, D] tile."""
    h = h_ref[0]  # [N, D]
    adj = adj_ref[0]  # [N, N]
    w_src = wsrc_ref[...]  # [D, H]
    w_dst = wdst_ref[...]  # [D, H]

    src = jnp.dot(h, w_src)  # [N, H]  (MXU)
    dst = jnp.dot(h, w_dst)  # [N, H]  (MXU)
    e = src[:, None, :] + dst[None, :, :]  # [N, N, H]
    e = jnp.where(e > 0, e, LEAKY_SLOPE * e)
    mask = (adj > 0)[:, :, None]
    e = jnp.where(mask, e, -1e9)
    e = e - jnp.max(e, axis=1, keepdims=True)
    w = jnp.exp(e) * mask
    denom = jnp.sum(w, axis=1, keepdims=True)
    alpha = w / jnp.maximum(denom, 1e-9)  # [N, N, H]
    # Aggregate: out[i, hd, :] = sum_j alpha[i, j, hd] * h[j, :]  (MXU)
    n, d = h.shape
    heads = alpha.shape[-1]
    alpha_t = jnp.transpose(alpha, (0, 2, 1)).reshape(n * heads, n)
    out = jnp.dot(alpha_t, h).reshape(n, heads, d)
    o_ref[0] = jnp.mean(out, axis=1)


def _gat_pallas(h, adj, w_src, w_dst):
    b, n, d = h.shape
    return pl.pallas_call(
        _gat_kernel,
        out_shape=jax.ShapeDtypeStruct((b, n, d), h.dtype),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, n, n * 0 + d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec(w_src.shape, lambda i: (0, 0)),
            pl.BlockSpec(w_dst.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
        interpret=True,
    )(h, adj, w_src, w_dst)


def _gat_ref_batched(h, adj, w_src, w_dst):
    """vmapped pure-jnp reference (used for the custom VJP backward)."""
    from .ref import gat_attention_ref

    return jax.vmap(lambda hh, aa: gat_attention_ref(hh, aa, w_src, w_dst))(h, adj)


@jax.custom_vjp
def gat_attention(h, adj, w_src, w_dst):
    """Batched GAT aggregation.

    Args:
      h:     [B, N, D] projected node features.
      adj:   [B, N, N] 0/1 adjacency (self loops included for live nodes).
      w_src: [D, H] receiving-node score projection.
      w_dst: [D, H] sending-node score projection.

    Returns:
      [B, N, D] aggregated features (mean over the H heads).

    Forward runs the Pallas kernel; the backward is the VJP of the
    numerically identical jnp reference (Pallas interpret kernels do not
    support reverse-mode AD directly).
    """
    return _gat_pallas(h, adj, w_src, w_dst)


def _gat_fwd(h, adj, w_src, w_dst):
    return _gat_pallas(h, adj, w_src, w_dst), (h, adj, w_src, w_dst)


def _gat_bwd(res, ct):
    h, adj, w_src, w_dst = res
    _, vjp = jax.vjp(lambda hh, ws, wd: _gat_ref_batched(hh, adj, ws, wd), h, w_src, w_dst)
    dh, dws, dwd = vjp(ct)
    return dh, jnp.zeros_like(adj), dws, dwd


gat_attention.defvjp(_gat_fwd, _gat_bwd)
