"""Pallas kernel: fused Adam update over a flat parameter vector.

Fuses the whole optimizer update (first/second moment EMA, bias
correction, parameter step) into one elementwise kernel — 4 HBM streams
in (p, g, m, v), 3 out — instead of the ~10 separate elementwise kernels
an unfused optimizer issues. The grid tiles the flat vector in
``BLOCK``-element chunks (the HBM↔VMEM pipeline); callers pad the vector
to a multiple of ``BLOCK`` (zero-padded tail is a fixed point of the
update: g = m = v = 0 ⇒ p unchanged).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ADAM_B1, ADAM_B2, ADAM_EPS

BLOCK = 1024


def _adam_kernel(lr, p_ref, g_ref, m_ref, v_ref, t_ref, po_ref, mo_ref, vo_ref):
    p = p_ref[...]
    g = g_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    t = t_ref[0]
    m_new = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v_new = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    m_hat = m_new / (1.0 - ADAM_B1**t)
    v_hat = v_new / (1.0 - ADAM_B2**t)
    po_ref[...] = p - lr * m_hat / (jnp.sqrt(v_hat) + ADAM_EPS)
    mo_ref[...] = m_new
    vo_ref[...] = v_new


@functools.partial(jax.jit, static_argnames=("lr",))
def adam_update(p, g, m, v, t, lr=1e-3):
    """One fused Adam step on flat vectors.

    Args:
      p, g, m, v: [P] f32 with P a multiple of ``BLOCK``.
      t: [1] f32, the 1-based step count.
      lr: learning rate (compile-time constant).

    Returns: (p_new, m_new, v_new), each [P].
    """
    (n,) = p.shape
    assert n % BLOCK == 0, f"flat parameter length {n} not a multiple of {BLOCK}"
    grid = (n // BLOCK,)
    vec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_adam_kernel, lr),
        out_shape=(jax.ShapeDtypeStruct((n,), p.dtype),) * 3,
        grid=grid,
        in_specs=[vec, vec, vec, vec, scalar],
        out_specs=(vec, vec, vec),
        interpret=True,
    )(p, g, m, v, t)
