"""Pallas kernel: fused causal attention (transformer hot-spot).

One (batch, head) pair per grid step: the [S, D] Q/K/V tiles live in VMEM
(S ≤ 256, D ≤ 128 → ≤ 384 KiB), the S×S score matrix never round-trips to
HBM — the same intermediate-elimination the paper's op fusion performs,
expressed as a kernel. Scores and context are MXU matmuls.

The GPU flash-attention formulation (threadblock tiling over KV chunks
with online softmax) is re-thought for TPU per DESIGN.md §3: with S ≤ 256
an entire head's working set fits VMEM, so a single-block masked softmax
is the better schedule; for longer sequences the grid would tile S with
BlockSpec and carry running max/denominator in scratch.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0, 0]  # [S, D]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    s, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    scores = jnp.dot(q, k.T) * scale  # [S, S] (MXU)
    row = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    scores = jnp.where(col <= row, scores, -1e9)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    w = jnp.exp(scores)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(w.astype(v.dtype), v)  # (MXU)


def _attn_pallas(q, k, v):
    b, h, s, d = q.shape
    spec = pl.BlockSpec((1, 1, s, d), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        _attn_kernel,
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        grid=(b, h),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        interpret=True,
    )(q, k, v)


@jax.custom_vjp
def causal_attention(q, k, v):
    """Fused causal attention.

    Args: q, k, v: [B, H, S, D].
    Returns: [B, H, S, D].

    Forward runs the Pallas kernel; backward is the VJP of the identical
    jnp reference (interpret-mode Pallas has no reverse-mode AD).
    """
    return _attn_pallas(q, k, v)


def _causal_fwd(q, k, v):
    return _attn_pallas(q, k, v), (q, k, v)


def _causal_bwd(res, ct):
    from .ref import causal_attention_ref

    q, k, v = res
    _, vjp = jax.vjp(causal_attention_ref, q, k, v)
    return vjp(ct)


causal_attention.defvjp(_causal_fwd, _causal_bwd)
