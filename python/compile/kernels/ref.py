"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package has a
reference here, and ``python/tests`` sweeps shapes/dtypes with hypothesis
asserting allclose between kernel and reference.
"""

import jax.numpy as jnp

LEAKY_SLOPE = 0.2
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def gat_attention_ref(h, adj, w_src, w_dst):
    """Masked multi-head graph-attention aggregation (one graph).

    Args:
      h:     [N, D]  node features (already linearly projected).
      adj:   [N, N]  0/1 adjacency, adj[i, j] = 1 when j may attend into i
             (i.e. j is a neighbour whose message i aggregates). Self loops
             must be included for nodes that exist; padded nodes have
             all-zero rows and produce zero output.
      w_src: [D, H]  per-head receiving-node score projection.
      w_dst: [D, H]  per-head sending-node score projection.

    Returns:
      [N, D] aggregated node features (mean over heads).
    """
    src = h @ w_src  # [N, H]
    dst = h @ w_dst  # [N, H]
    e = src[:, None, :] + dst[None, :, :]  # [N, N, H]
    e = jnp.where(e > 0, e, LEAKY_SLOPE * e)
    mask = (adj > 0)[:, :, None]  # [N, N, 1]
    e = jnp.where(mask, e, -1e9)
    e = e - jnp.max(e, axis=1, keepdims=True)
    w = jnp.exp(e) * mask
    denom = jnp.sum(w, axis=1, keepdims=True)
    alpha = w / jnp.maximum(denom, 1e-9)  # [N, N, H]
    out = jnp.einsum("ijh,jd->ihd", alpha, h)  # [N, H, D]
    return jnp.mean(out, axis=1)  # [N, D]


def causal_attention_ref(q, k, v):
    """Causal scaled-dot-product attention.

    Args:  q, k, v: [B, H, S, D].
    Returns: [B, H, S, D].
    """
    d = q.shape[-1]
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) / jnp.sqrt(jnp.asarray(d, q.dtype))
    s = q.shape[2]
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(causal[None, None, :, :], scores, -1e9)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    w = jnp.exp(scores)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.einsum("bhst,bhtd->bhsd", w, v)


def adam_update_ref(p, g, m, v, t, lr=1e-3, b1=ADAM_B1, b2=ADAM_B2, eps=ADAM_EPS):
    """One Adam step. ``t`` is the 1-based step count (scalar).

    Returns (p_new, m_new, v_new).
    """
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    m_hat = m_new / (1.0 - b1**t)
    v_hat = v_new / (1.0 - b2**t)
    p_new = p - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    return p_new, m_new, v_new
