"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO).

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls, so real-TPU lowering is compile-only here
(see DESIGN.md §3 Hardware adaptation).
"""

from .gat import gat_attention
from .attention import causal_attention
from .adam import adam_update

__all__ = ["gat_attention", "causal_attention", "adam_update"]
