"""AOT compiler: lower the L2 JAX computations (with their L1 Pallas
kernels) to HLO **text** artifacts the Rust runtime loads via PJRT.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids, which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (``--out artifacts/``):
  gnn_infer.hlo.txt    estimator forward  (search-time cost model)
  gnn_train.hlo.txt    estimator fwd+bwd+Adam step
  lm_grads.hlo.txt     LM loss+gradients (per worker)
  lm_adam.hlo.txt      fused-Adam parameter update
  lm_eval.hlo.txt      LM held-out loss
  gnn_params.f32       initial flat estimator parameters (LE f32)
  lm_params.f32        initial flat LM parameters (LE f32)
  manifest.json        shapes/dtypes of every artifact's inputs/outputs

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .model import LMConfig


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def export(fn, args, path):
    """Lower ``fn`` at the abstract ``args`` and write HLO text to ``path``.
    Returns (input_specs, output_specs) for the manifest."""
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    out_shapes = jax.eval_shape(fn, *args)
    outs = jax.tree_util.tree_leaves(out_shapes)
    ins = jax.tree_util.tree_leaves(args)
    fmt = lambda s: {"shape": list(s.shape), "dtype": str(s.dtype)}
    return [fmt(s) for s in ins], [fmt(s) for s in outs]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--lm-d", type=int, default=128)
    ap.add_argument("--lm-layers", type=int, default=2)
    ap.add_argument("--lm-heads", type=int, default=4)
    ap.add_argument("--lm-ff", type=int, default=512)
    ap.add_argument("--lm-seq", type=int, default=64)
    ap.add_argument("--lm-batch", type=int, default=8)
    ap.add_argument("--lm-vocab", type=int, default=256)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = {"artifacts": {}}

    # --- GNN estimator -----------------------------------------------------
    gnn_p, _, gnn_init = model.gnn_flat_spec()
    infer, train = model.make_gnn_fns()
    B, N, F = model.GNN_BATCH, model.MAX_NODES, model.FEAT_DIM

    ins, outs = export(
        infer,
        (spec((gnn_p,)), spec((B, N, F)), spec((B, N, N)), spec((B, N))),
        os.path.join(args.out, "gnn_infer.hlo.txt"),
    )
    manifest["artifacts"]["gnn_infer"] = {
        "file": "gnn_infer.hlo.txt", "inputs": ins, "outputs": outs,
    }

    ins, outs = export(
        train,
        (
            spec((gnn_p,)), spec((gnn_p,)), spec((gnn_p,)), spec((1,)),
            spec((B, N, F)), spec((B, N, N)), spec((B, N)), spec((B,)),
        ),
        os.path.join(args.out, "gnn_train.hlo.txt"),
    )
    manifest["artifacts"]["gnn_train"] = {
        "file": "gnn_train.hlo.txt", "inputs": ins, "outputs": outs,
    }
    np.asarray(gnn_init, dtype="<f4").tofile(os.path.join(args.out, "gnn_params.f32"))
    manifest["gnn"] = {
        "params": "gnn_params.f32", "flat_len": int(gnn_p), "batch": B,
        "max_nodes": N, "feat_dim": F, "n_op_kinds": model.N_OP_KINDS,
        "lr": model.GNN_LR,
    }

    # --- Transformer LM -----------------------------------------------------
    cfg = LMConfig(
        vocab=args.lm_vocab, d_model=args.lm_d, n_heads=args.lm_heads,
        n_layers=args.lm_layers, d_ff=args.lm_ff, seq=args.lm_seq,
        batch=args.lm_batch,
    )
    lm_p, _, lm_init = model.lm_flat_spec(cfg)
    grads, adam, evaluate = model.make_lm_fns(cfg)
    tok = spec((cfg.batch, cfg.seq + 1), jnp.int32)

    ins, outs = export(grads, (spec((lm_p,)), tok), os.path.join(args.out, "lm_grads.hlo.txt"))
    manifest["artifacts"]["lm_grads"] = {
        "file": "lm_grads.hlo.txt", "inputs": ins, "outputs": outs,
    }
    ins, outs = export(
        adam,
        (spec((lm_p,)), spec((lm_p,)), spec((lm_p,)), spec((lm_p,)), spec((1,))),
        os.path.join(args.out, "lm_adam.hlo.txt"),
    )
    manifest["artifacts"]["lm_adam"] = {
        "file": "lm_adam.hlo.txt", "inputs": ins, "outputs": outs,
    }
    ins, outs = export(evaluate, (spec((lm_p,)), tok), os.path.join(args.out, "lm_eval.hlo.txt"))
    manifest["artifacts"]["lm_eval"] = {
        "file": "lm_eval.hlo.txt", "inputs": ins, "outputs": outs,
    }
    np.asarray(lm_init, dtype="<f4").tofile(os.path.join(args.out, "lm_params.f32"))
    manifest["lm"] = {
        "params": "lm_params.f32", "flat_len": int(lm_p),
        "vocab": cfg.vocab, "d_model": cfg.d_model, "n_heads": cfg.n_heads,
        "n_layers": cfg.n_layers, "d_ff": cfg.d_ff, "seq": cfg.seq,
        "batch": cfg.batch, "lr": cfg.lr,
        "param_count": int(lm_p),
    }

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out} "
          f"(gnn flat={gnn_p}, lm flat={lm_p}, lm: {cfg.describe()})")


if __name__ == "__main__":
    main()
